//! Resident-particle cache: the paper's per-device *active set* (§4.2).
//!
//! Particles in the active set live "on the accelerator" (here: owned by
//! the device thread); the rest live in the shared host store. A compute
//! job touching a non-resident particle triggers the paper's context
//! switch: evict the LRU unpinned particle (swap-out copy back to host),
//! then swap the target in. Both directions perform REAL copies so the
//! measured cost of cache pressure is honest, and are additionally charged
//! to the virtual transfer clock (cost::CostModel).

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::device::cost::CostModel;
use crate::device::stats::DeviceStats;
use crate::nel::trace::{Event, EventKind, Trace};
use crate::particle::Pid;
use crate::runtime::Tensor;

/// Host-RAM parameter store, shared by all devices. A particle's parameters
/// are EITHER here or resident in exactly one device cache (the invariant
/// `swap-out inserts / swap-in removes` maintains single authority).
#[derive(Clone, Default)]
pub struct HostStore {
    inner: Arc<Mutex<HashMap<Pid, Tensor>>>,
}

impl HostStore {
    pub fn insert(&self, pid: Pid, t: Tensor) {
        self.inner.lock().unwrap().insert(pid, t);
    }

    pub fn take(&self, pid: Pid) -> Option<Tensor> {
        self.inner.lock().unwrap().remove(&pid)
    }

    pub fn get_clone(&self, pid: Pid) -> Option<Tensor> {
        self.inner.lock().unwrap().get(&pid).cloned()
    }

    pub fn contains(&self, pid: Pid) -> bool {
        self.inner.lock().unwrap().contains_key(&pid)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

pub struct ResidentCache {
    capacity: usize,
    mem_budget: usize,
    cost: CostModel,
    resident: HashMap<Pid, Tensor>,
    /// LRU order: front = least recently used.
    lru: VecDeque<Pid>,
    bytes: usize,
}

impl ResidentCache {
    pub fn new(capacity: usize, mem_budget: usize, cost: CostModel) -> ResidentCache {
        assert!(capacity > 0, "active set must hold at least one particle");
        ResidentCache {
            capacity,
            mem_budget,
            cost,
            resident: HashMap::new(),
            lru: VecDeque::new(),
            bytes: 0,
        }
    }

    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    pub fn resident_bytes(&self) -> usize {
        self.bytes
    }

    pub fn is_resident(&self, pid: Pid) -> bool {
        self.resident.contains_key(&pid)
    }

    fn touch(&mut self, pid: Pid) {
        if let Some(pos) = self.lru.iter().position(|p| *p == pid) {
            self.lru.remove(pos);
        }
        self.lru.push_back(pid);
    }

    /// Swap in `pid` (evicting as needed) and return its parameters.
    pub fn ensure_resident(
        &mut self,
        pid: Pid,
        host: &HostStore,
        stats: &mut DeviceStats,
        trace: &Trace,
        device: usize,
    ) -> Result<&mut Tensor> {
        if self.resident.contains_key(&pid) {
            self.touch(pid);
            stats.cache_hits += 1;
            return Ok(self.resident.get_mut(&pid).unwrap());
        }
        stats.cache_misses += 1;
        let t = host.take(pid).ok_or_else(|| {
            anyhow!("particle {pid:?} is neither resident on device {device} nor in the host store (resident elsewhere?)")
        })?;
        let incoming = t.size_bytes();

        // Evict until both the slot budget and the byte budget fit.
        while self.resident.len() >= self.capacity
            || (self.bytes + incoming > self.mem_budget && !self.resident.is_empty())
        {
            let victim = self
                .lru
                .pop_front()
                .ok_or_else(|| anyhow!("cache bookkeeping lost its LRU order"))?;
            let vt = self
                .resident
                .remove(&victim)
                .ok_or_else(|| anyhow!("LRU entry {victim:?} not resident"))?;
            let vbytes = vt.size_bytes();
            self.bytes -= vbytes;
            self.cost.charge_swap(vbytes, stats);
            stats.swaps_out += 1;
            stats.swap_bytes += vbytes as u64;
            trace.record(Event::new(device, Some(victim), EventKind::SwapOut, vbytes));
            host.insert(victim, vt);
        }

        self.cost.charge_swap(incoming, stats);
        stats.swaps_in += 1;
        stats.swap_bytes += incoming as u64;
        trace.record(Event::new(device, Some(pid), EventKind::SwapIn, incoming));
        self.bytes += incoming;
        self.resident.insert(pid, t);
        self.lru.push_back(pid);
        Ok(self.resident.get_mut(&pid).unwrap())
    }

    /// Write a resident particle back to the host store (used on particle
    /// drop and by the drain API that snapshots all parameters).
    pub fn flush(&mut self, pid: Pid, host: &HostStore) -> bool {
        if let Some(t) = self.resident.remove(&pid) {
            self.bytes -= t.size_bytes();
            if let Some(pos) = self.lru.iter().position(|p| *p == pid) {
                self.lru.remove(pos);
            }
            host.insert(pid, t);
            true
        } else {
            false
        }
    }

    /// Flush everything (drain before reading a global snapshot).
    pub fn flush_all(&mut self, host: &HostStore) -> usize {
        let pids: Vec<Pid> = self.resident.keys().copied().collect();
        let n = pids.len();
        for pid in pids {
            self.flush(pid, host);
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(pid: u32, elems: usize) -> (Pid, Tensor) {
        (Pid(pid), Tensor::f32(vec![elems], vec![pid as f32; elems]))
    }

    fn setup(cap: usize, budget: usize) -> (ResidentCache, HostStore, DeviceStats, Trace) {
        (
            ResidentCache::new(cap, budget, CostModel::default()),
            HostStore::default(),
            DeviceStats::default(),
            Trace::disabled(),
        )
    }

    #[test]
    fn swap_in_and_hit() {
        let (mut c, host, mut st, tr) = setup(2, 1 << 20);
        let (p, t) = mk(1, 4);
        host.insert(p, t);
        c.ensure_resident(p, &host, &mut st, &tr, 0).unwrap();
        assert!(c.is_resident(p));
        assert!(!host.contains(p), "authority moved to device");
        c.ensure_resident(p, &host, &mut st, &tr, 0).unwrap();
        assert_eq!(st.cache_hits, 1);
        assert_eq!(st.swaps_in, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let (mut c, host, mut st, tr) = setup(2, 1 << 20);
        for i in 1..=3 {
            let (p, t) = mk(i, 4);
            host.insert(p, t);
        }
        c.ensure_resident(Pid(1), &host, &mut st, &tr, 0).unwrap();
        c.ensure_resident(Pid(2), &host, &mut st, &tr, 0).unwrap();
        // touch 1 so 2 becomes LRU
        c.ensure_resident(Pid(1), &host, &mut st, &tr, 0).unwrap();
        c.ensure_resident(Pid(3), &host, &mut st, &tr, 0).unwrap();
        assert!(c.is_resident(Pid(1)));
        assert!(!c.is_resident(Pid(2)), "2 was LRU, must be evicted");
        assert!(host.contains(Pid(2)), "evicted particle back in host store");
        assert_eq!(st.swaps_out, 1);
    }

    #[test]
    fn byte_budget_evicts() {
        // budget of 40 bytes = 10 f32; two 4-elem tensors fit, a third evicts
        let (mut c, host, mut st, tr) = setup(8, 40);
        for i in 1..=3 {
            let (p, t) = mk(i, 4); // 16 bytes each
            host.insert(p, t);
        }
        c.ensure_resident(Pid(1), &host, &mut st, &tr, 0).unwrap();
        c.ensure_resident(Pid(2), &host, &mut st, &tr, 0).unwrap();
        c.ensure_resident(Pid(3), &host, &mut st, &tr, 0).unwrap();
        assert_eq!(c.resident_count(), 2);
        assert!(c.resident_bytes() <= 40);
    }

    #[test]
    fn missing_particle_errors() {
        let (mut c, host, mut st, tr) = setup(2, 1 << 20);
        assert!(c.ensure_resident(Pid(9), &host, &mut st, &tr, 0).is_err());
    }

    #[test]
    fn flush_restores_authority() {
        let (mut c, host, mut st, tr) = setup(2, 1 << 20);
        let (p, t) = mk(5, 4);
        host.insert(p, t.clone());
        c.ensure_resident(p, &host, &mut st, &tr, 0).unwrap();
        assert!(c.flush(p, &host));
        assert_eq!(host.get_clone(p).unwrap(), t);
        assert!(!c.flush(p, &host), "double flush is a no-op");
    }

    #[test]
    fn mutation_survives_roundtrip() {
        let (mut c, host, mut st, tr) = setup(1, 1 << 20);
        for i in 1..=2 {
            let (p, t) = mk(i, 4);
            host.insert(p, t);
        }
        c.ensure_resident(Pid(1), &host, &mut st, &tr, 0)
            .unwrap()
            .as_f32_mut()[0] = 99.0;
        // forces eviction of 1
        c.ensure_resident(Pid(2), &host, &mut st, &tr, 0).unwrap();
        assert_eq!(host.get_clone(Pid(1)).unwrap().as_f32()[0], 99.0);
    }
}
