//! Resident-particle cache: the paper's per-device *active set* (§4.2).
//!
//! Particles in the active set live "on the accelerator" (here: owned by
//! the device thread); the rest live in the shared host store. A compute
//! job touching a non-resident particle triggers the paper's context
//! switch: evict the LRU unpinned particle (swap-out back to host), then
//! swap the target in.
//!
//! # Zero-copy swaps, honest accounting
//!
//! Since the tensor plane went Arc-backed (runtime::tensor), a swap moves
//! the parameter buffer's Arc between the cache and the host store — no
//! data copy. The *logical* swap bytes are still charged to the virtual
//! transfer clock (cost::CostModel) and to `DeviceStats::swap_bytes`, so
//! the measured cost of cache pressure models a real accelerator even
//! though the host-side memcpy is gone. Single authority is unchanged: a
//! particle's parameters are owned EITHER by the host store or by exactly
//! one device cache; read-only snapshots taken elsewhere are COW-isolated.
//!
//! The LRU order is an intrusive doubly-linked list threaded through the
//! slot map (`head` = least recently used), so touch/evict are O(1) —
//! the previous `VecDeque` implementation rescanned O(n) per access.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::device::cost::CostModel;
use crate::device::stats::DeviceStats;
use crate::nel::trace::{Event, EventKind, Trace};
use crate::particle::Pid;
use crate::runtime::Tensor;

/// Host-RAM parameter store, shared by all devices. A particle's parameters
/// are EITHER here or resident in exactly one device cache (the invariant
/// `swap-out inserts / swap-in removes` maintains single authority).
#[derive(Clone, Default)]
pub struct HostStore {
    inner: Arc<Mutex<HashMap<Pid, Tensor>>>,
}

impl HostStore {
    pub fn insert(&self, pid: Pid, t: Tensor) {
        self.inner.lock().unwrap().insert(pid, t);
    }

    pub fn take(&self, pid: Pid) -> Option<Tensor> {
        self.inner.lock().unwrap().remove(&pid)
    }

    /// Zero-copy snapshot: a clone of the stored tensor shares its buffer
    /// (COW isolates later writers), so drain/checkpoint reads are free.
    pub fn get_clone(&self, pid: Pid) -> Option<Tensor> {
        self.inner.lock().unwrap().get(&pid).cloned()
    }

    pub fn contains(&self, pid: Pid) -> bool {
        self.inner.lock().unwrap().contains_key(&pid)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One resident particle: its parameters plus intrusive LRU links.
struct Slot {
    t: Tensor,
    /// Toward the LRU end (`None` = this is the LRU head).
    prev: Option<Pid>,
    /// Toward the MRU end (`None` = this is the MRU tail).
    next: Option<Pid>,
}

pub struct ResidentCache {
    capacity: usize,
    mem_budget: usize,
    cost: CostModel,
    slots: HashMap<Pid, Slot>,
    /// Least recently used (first eviction victim).
    head: Option<Pid>,
    /// Most recently used.
    tail: Option<Pid>,
    bytes: usize,
}

impl ResidentCache {
    pub fn new(capacity: usize, mem_budget: usize, cost: CostModel) -> ResidentCache {
        assert!(capacity > 0, "active set must hold at least one particle");
        ResidentCache {
            capacity,
            mem_budget,
            cost,
            slots: HashMap::new(),
            head: None,
            tail: None,
            bytes: 0,
        }
    }

    pub fn resident_count(&self) -> usize {
        self.slots.len()
    }

    pub fn resident_bytes(&self) -> usize {
        self.bytes
    }

    pub fn is_resident(&self, pid: Pid) -> bool {
        self.slots.contains_key(&pid)
    }

    /// Unlink `pid` from the LRU list (slot stays in the map). O(1).
    fn detach(&mut self, pid: Pid) {
        let (prev, next) = {
            let s = self.slots.get(&pid).expect("detach of non-resident pid");
            (s.prev, s.next)
        };
        match prev {
            Some(p) => self.slots.get_mut(&p).unwrap().next = next,
            None => self.head = next,
        }
        match next {
            Some(n) => self.slots.get_mut(&n).unwrap().prev = prev,
            None => self.tail = prev,
        }
    }

    /// Link `pid` at the MRU end. O(1).
    fn attach_mru(&mut self, pid: Pid) {
        let old_tail = self.tail;
        {
            let s = self.slots.get_mut(&pid).expect("attach of non-resident pid");
            s.prev = old_tail;
            s.next = None;
        }
        match old_tail {
            Some(t) => self.slots.get_mut(&t).unwrap().next = Some(pid),
            None => self.head = Some(pid),
        }
        self.tail = Some(pid);
    }

    fn touch(&mut self, pid: Pid) {
        if self.tail == Some(pid) {
            return;
        }
        self.detach(pid);
        self.attach_mru(pid);
    }

    /// Swap in `pid` (evicting as needed) and return its parameters.
    pub fn ensure_resident(
        &mut self,
        pid: Pid,
        host: &HostStore,
        stats: &mut DeviceStats,
        trace: &Trace,
        device: usize,
    ) -> Result<&mut Tensor> {
        if self.slots.contains_key(&pid) {
            self.touch(pid);
            stats.cache_hits += 1;
            return Ok(&mut self.slots.get_mut(&pid).unwrap().t);
        }
        stats.cache_misses += 1;
        let t = host.take(pid).ok_or_else(|| {
            anyhow!("particle {pid:?} is neither resident on device {device} nor in the host store (resident elsewhere?)")
        })?;
        let incoming = t.size_bytes();

        // Evict until both the slot budget and the byte budget fit. The
        // victim's buffer MOVES back to the host store (refcount transfer,
        // no copy); the modeled cost still charges the full logical bytes.
        while self.slots.len() >= self.capacity
            || (self.bytes + incoming > self.mem_budget && !self.slots.is_empty())
        {
            let victim = self
                .head
                .ok_or_else(|| anyhow!("cache bookkeeping lost its LRU order"))?;
            self.detach(victim);
            let slot = self
                .slots
                .remove(&victim)
                .ok_or_else(|| anyhow!("LRU entry {victim:?} not resident"))?;
            let vbytes = slot.t.size_bytes();
            self.bytes -= vbytes;
            self.cost.charge_swap(vbytes, stats);
            stats.swaps_out += 1;
            stats.swap_bytes += vbytes as u64;
            trace.record(Event::new(device, Some(victim), EventKind::SwapOut, vbytes));
            host.insert(victim, slot.t);
        }

        self.cost.charge_swap(incoming, stats);
        stats.swaps_in += 1;
        stats.swap_bytes += incoming as u64;
        trace.record(Event::new(device, Some(pid), EventKind::SwapIn, incoming));
        self.bytes += incoming;
        self.slots.insert(pid, Slot { t, prev: None, next: None });
        self.attach_mru(pid);
        Ok(&mut self.slots.get_mut(&pid).unwrap().t)
    }

    /// Write a resident particle back to the host store (used on particle
    /// drop and by the drain API that snapshots all parameters). Moves the
    /// buffer — no copy.
    pub fn flush(&mut self, pid: Pid, host: &HostStore) -> bool {
        if !self.slots.contains_key(&pid) {
            return false;
        }
        self.detach(pid);
        let slot = self.slots.remove(&pid).unwrap();
        self.bytes -= slot.t.size_bytes();
        host.insert(pid, slot.t);
        true
    }

    /// Flush everything (drain before reading a global snapshot).
    pub fn flush_all(&mut self, host: &HostStore) -> usize {
        let pids: Vec<Pid> = self.slots.keys().copied().collect();
        let n = pids.len();
        for pid in pids {
            self.flush(pid, host);
        }
        n
    }

    /// LRU -> MRU order walk, for tests and debugging.
    #[cfg(test)]
    fn lru_order(&self) -> Vec<Pid> {
        let mut out = Vec::with_capacity(self.slots.len());
        let mut cur = self.head;
        while let Some(pid) = cur {
            out.push(pid);
            cur = self.slots[&pid].next;
        }
        assert_eq!(out.len(), self.slots.len(), "LRU list desynced from slots");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(pid: u32, elems: usize) -> (Pid, Tensor) {
        (Pid(pid), Tensor::f32(vec![elems], vec![pid as f32; elems]))
    }

    fn setup(cap: usize, budget: usize) -> (ResidentCache, HostStore, DeviceStats, Trace) {
        (
            ResidentCache::new(cap, budget, CostModel::default()),
            HostStore::default(),
            DeviceStats::default(),
            Trace::disabled(),
        )
    }

    #[test]
    fn swap_in_and_hit() {
        let (mut c, host, mut st, tr) = setup(2, 1 << 20);
        let (p, t) = mk(1, 4);
        host.insert(p, t);
        c.ensure_resident(p, &host, &mut st, &tr, 0).unwrap();
        assert!(c.is_resident(p));
        assert!(!host.contains(p), "authority moved to device");
        c.ensure_resident(p, &host, &mut st, &tr, 0).unwrap();
        assert_eq!(st.cache_hits, 1);
        assert_eq!(st.swaps_in, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let (mut c, host, mut st, tr) = setup(2, 1 << 20);
        for i in 1..=3 {
            let (p, t) = mk(i, 4);
            host.insert(p, t);
        }
        c.ensure_resident(Pid(1), &host, &mut st, &tr, 0).unwrap();
        c.ensure_resident(Pid(2), &host, &mut st, &tr, 0).unwrap();
        assert_eq!(c.lru_order(), vec![Pid(1), Pid(2)]);
        // touch 1 so 2 becomes LRU
        c.ensure_resident(Pid(1), &host, &mut st, &tr, 0).unwrap();
        assert_eq!(c.lru_order(), vec![Pid(2), Pid(1)]);
        c.ensure_resident(Pid(3), &host, &mut st, &tr, 0).unwrap();
        assert!(c.is_resident(Pid(1)));
        assert!(!c.is_resident(Pid(2)), "2 was LRU, must be evicted");
        assert!(host.contains(Pid(2)), "evicted particle back in host store");
        assert_eq!(st.swaps_out, 1);
        assert_eq!(c.lru_order(), vec![Pid(1), Pid(3)]);
    }

    #[test]
    fn byte_budget_evicts() {
        // budget of 40 bytes = 10 f32; two 4-elem tensors fit, a third evicts
        let (mut c, host, mut st, tr) = setup(8, 40);
        for i in 1..=3 {
            let (p, t) = mk(i, 4); // 16 bytes each
            host.insert(p, t);
        }
        c.ensure_resident(Pid(1), &host, &mut st, &tr, 0).unwrap();
        c.ensure_resident(Pid(2), &host, &mut st, &tr, 0).unwrap();
        c.ensure_resident(Pid(3), &host, &mut st, &tr, 0).unwrap();
        assert_eq!(c.resident_count(), 2);
        assert!(c.resident_bytes() <= 40);
    }

    #[test]
    fn missing_particle_errors() {
        let (mut c, host, mut st, tr) = setup(2, 1 << 20);
        assert!(c.ensure_resident(Pid(9), &host, &mut st, &tr, 0).is_err());
    }

    #[test]
    fn flush_restores_authority() {
        let (mut c, host, mut st, tr) = setup(2, 1 << 20);
        let (p, t) = mk(5, 4);
        host.insert(p, t.clone());
        c.ensure_resident(p, &host, &mut st, &tr, 0).unwrap();
        assert!(c.flush(p, &host));
        assert_eq!(host.get_clone(p).unwrap(), t);
        assert!(!c.flush(p, &host), "double flush is a no-op");
        assert!(c.lru_order().is_empty());
    }

    #[test]
    fn mutation_survives_roundtrip() {
        let (mut c, host, mut st, tr) = setup(1, 1 << 20);
        for i in 1..=2 {
            let (p, t) = mk(i, 4);
            host.insert(p, t);
        }
        c.ensure_resident(Pid(1), &host, &mut st, &tr, 0)
            .unwrap()
            .as_f32_mut()[0] = 99.0;
        // forces eviction of 1
        c.ensure_resident(Pid(2), &host, &mut st, &tr, 0).unwrap();
        assert_eq!(host.get_clone(Pid(1)).unwrap().as_f32()[0], 99.0);
    }

    #[test]
    fn swap_bytes_charged_but_not_copied() {
        // The acceptance check for the zero-copy plane: a full swap-out /
        // swap-in cycle charges the logical bytes to the stats while the
        // backing buffer is MOVED (same allocation end to end).
        let (mut c, host, mut st, tr) = setup(1, 1 << 20);
        let (p1, t1) = mk(1, 8); // 32 bytes
        let probe = t1.clone(); // shares t1's buffer
        host.insert(p1, t1);
        c.ensure_resident(p1, &host, &mut st, &tr, 0).unwrap();
        assert_eq!(st.swap_bytes, 32, "swap-in charged");
        let (p2, t2) = mk(2, 8);
        host.insert(p2, t2);
        c.ensure_resident(p2, &host, &mut st, &tr, 0).unwrap(); // evicts p1
        assert_eq!(st.swap_bytes, 32 * 3, "swap-out + second swap-in charged");
        let back = host.get_clone(p1).unwrap();
        assert!(
            back.shares_storage(&probe),
            "swap must move the Arc, not memcpy the parameters"
        );
    }

    #[test]
    fn snapshot_immune_to_later_resident_mutation() {
        // params_view-style snapshot: clone the resident tensor, then
        // mutate the resident copy — COW must isolate the snapshot.
        let (mut c, host, mut st, tr) = setup(2, 1 << 20);
        let (p, t) = mk(3, 4);
        host.insert(p, t);
        let snapshot = c
            .ensure_resident(p, &host, &mut st, &tr, 0)
            .unwrap()
            .clone();
        let resident = c.ensure_resident(p, &host, &mut st, &tr, 0).unwrap();
        assert!(snapshot.shares_storage(resident), "view is zero-copy");
        resident.as_f32_mut()[0] = -1.0;
        assert_eq!(snapshot.as_f32()[0], 3.0, "snapshot unchanged");
        let resident = c.ensure_resident(p, &host, &mut st, &tr, 0).unwrap();
        assert!(!snapshot.shares_storage(resident), "write detached");
    }
}
