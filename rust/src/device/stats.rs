//! Per-device counters surfaced by `push bench ... --stats` and consumed by
//! the perf pass (EXPERIMENTS.md §Perf).

use crate::runtime::ClientStats;

#[derive(Debug, Default, Clone)]
pub struct DeviceStats {
    /// Compute jobs executed on this device's stream.
    pub jobs: u64,
    /// Wall time spent executing jobs (busy time).
    pub busy_secs: f64,

    // --- particle cache (active set) ---
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub swaps_in: u64,
    pub swaps_out: u64,
    pub swap_bytes: u64,

    // --- parameter views / cross-particle reads ---
    pub views: u64,
    pub view_bytes: u64,

    // --- messaging transfers charged to this device ---
    pub transfers: u64,
    pub transfer_bytes: u64,

    // --- virtual clock from the cost model ---
    pub modeled_swap_secs: f64,
    pub modeled_transfer_secs: f64,

    // --- PJRT client counters ---
    pub client: ClientStats,
}

impl DeviceStats {
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// One-line human summary.
    pub fn summary(&self, id: usize) -> String {
        format!(
            "dev{id}: jobs={} busy={:.3}s exec={}({:.3}s) compile={}({:.1}s) \
             cache {}/{} hit={:.0}% swaps={}+{} ({} MB) views={} vclock={:.4}s",
            self.jobs,
            self.busy_secs,
            self.client.executions,
            self.client.execute_secs,
            self.client.compiles,
            self.client.compile_secs,
            self.cache_hits,
            self.cache_hits + self.cache_misses,
            100.0 * self.cache_hit_rate(),
            self.swaps_in,
            self.swaps_out,
            self.swap_bytes / (1 << 20),
            self.views,
            self.modeled_swap_secs + self.modeled_transfer_secs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate() {
        let mut s = DeviceStats::default();
        assert_eq!(s.cache_hit_rate(), 0.0);
        s.cache_hits = 3;
        s.cache_misses = 1;
        assert!((s.cache_hit_rate() - 0.75).abs() < 1e-12);
    }
}
