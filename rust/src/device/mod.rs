//! Simulated accelerator devices (DESIGN.md §Hardware-Adaptation).
//!
//! The paper maps particles onto physical GPUs; this testbed has none, so
//! each `SimDevice` is a dedicated OS thread with a FIFO compute stream, a
//! byte-budgeted resident-particle cache (the paper's *active set* +
//! *particle cache*, §4.2), and its own PJRT CPU client. Compute submitted
//! to a device executes for real — strictly serialized per device, truly
//! concurrent across devices — so contention and scheduling behave like the
//! paper's multi-GPU node while numerics stay exact.
//!
//! Compute jobs must never block on other jobs' results (blocking waits
//! belong in particle handlers on the control-worker pool, see nel::sched)
//! — device streams are kept deadlock-free by construction.
//!
//! Stats are published *on demand*: a `DeviceHandle::stats()` call enqueues
//! a request on the device stream and the worker replies with its local
//! counters. The request drains FIFO behind every previously submitted
//! job, so readers see a consistent snapshot without the old
//! clone-into-a-mutex-after-every-job publication on the hot path.

pub mod cache;
pub mod cost;
pub mod stats;

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::nel::trace::Trace;
use crate::particle::Pid;
use crate::runtime::{RuntimeClient, Tensor};
pub use cache::{HostStore, ResidentCache};
pub use cost::CostModel;
pub use stats::DeviceStats;

/// Context handed to every compute job, giving access to the device's PJRT
/// client, its resident-particle cache, and the shared host store.
pub struct DeviceCtx<'a> {
    pub device_id: usize,
    pub runtime: &'a mut RuntimeClient,
    pub cache: &'a mut ResidentCache,
    pub host: &'a HostStore,
    pub stats: &'a mut DeviceStats,
    pub trace: &'a Trace,
}

impl<'a> DeviceCtx<'a> {
    /// Ensure `pid`'s parameters are resident on this device (performing
    /// the swap-in / LRU eviction the paper's context switch does) and
    /// return a mutable reference to them.
    pub fn params_mut(&mut self, pid: Pid) -> Result<&mut Tensor> {
        self.cache
            .ensure_resident(pid, self.host, self.stats, self.trace, self.device_id)
    }

    /// Read-only snapshot of `pid`'s parameters (a *view* in the paper's
    /// sense). Zero-copy: the clone shares the resident buffer and COW
    /// isolates it from later mutation; the logical view bytes are still
    /// counted so transfer accounting models a real device->host copy.
    pub fn params_view(&mut self, pid: Pid) -> Result<Tensor> {
        let dev = self.device_id;
        let t = self
            .cache
            .ensure_resident(pid, self.host, self.stats, self.trace, dev)?
            .clone();
        self.stats.view_bytes += t.size_bytes() as u64;
        self.stats.views += 1;
        Ok(t)
    }
}

type Job = Box<dyn FnOnce(&mut DeviceCtx<'_>) + Send + 'static>;

enum Msg {
    Run(Job),
    /// Reply with a snapshot of the worker's local counters. Drains FIFO
    /// behind earlier jobs, so it doubles as a per-device barrier.
    Stats(Sender<DeviceStats>),
    Shutdown,
}

/// Handle to one simulated device's FIFO stream.
pub struct DeviceHandle {
    pub id: usize,
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
}

impl DeviceHandle {
    /// Enqueue a compute job. FIFO per device.
    pub fn submit(&self, job: Job) -> Result<()> {
        self.tx
            .send(Msg::Run(job))
            .map_err(|_| anyhow!("device {} stream closed", self.id))
    }

    /// Current counters, fetched from the worker thread on demand. Blocks
    /// until every previously enqueued job has finished (FIFO). Returns
    /// defaults if the worker died (e.g. PJRT client creation failed).
    pub fn stats(&self) -> DeviceStats {
        let (tx, rx) = channel();
        if self.tx.send(Msg::Stats(tx)).is_err() {
            return DeviceStats::default();
        }
        rx.recv().unwrap_or_default()
    }
}

/// Configuration for one device (uniform across the pool today).
#[derive(Clone)]
pub struct DeviceConfig {
    /// Max particles resident at once — the paper's active-set size
    /// ("cache_size" in its API).
    pub cache_size: usize,
    /// Device memory budget in bytes (24 GB on the paper's A5000s; scaled
    /// here, mostly exercised by the stress tests).
    pub mem_budget: usize,
    pub cost: CostModel,
    /// When set, every device stream acquires this lock around each job —
    /// discrete-event measurement mode for 1-core hosts: per-device busy
    /// times become contention-free, so `max_d(busy_d)` is an honest
    /// parallel makespan (DESIGN.md §Hardware-Adaptation). None = real
    /// thread-level concurrency.
    pub serialize: Option<Arc<Mutex<()>>>,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig {
            cache_size: 4,
            mem_budget: 2 << 30,
            cost: CostModel::default(),
            serialize: None,
        }
    }
}

/// The pool of simulated devices on this "node".
pub struct DevicePool {
    devices: Vec<DeviceHandle>,
    pub host: HostStore,
}

impl DevicePool {
    pub fn new(n: usize, cfg: DeviceConfig, trace: Trace) -> Result<DevicePool> {
        assert!(n > 0, "need at least one device");
        let host = HostStore::default();
        let mut devices = Vec::with_capacity(n);
        for id in 0..n {
            devices.push(Self::spawn(id, cfg.clone(), host.clone(), trace.clone())?);
        }
        Ok(DevicePool { devices, host })
    }

    fn spawn(id: usize, cfg: DeviceConfig, host: HostStore, trace: Trace) -> Result<DeviceHandle> {
        let (tx, rx) = channel::<Msg>();
        // RuntimeClient is created ON the worker thread (PJRT types are
        // !Send); creation failure is reported through the first join.
        let join = std::thread::Builder::new()
            .name(format!("sim-device-{id}"))
            .spawn(move || {
                let mut runtime = match RuntimeClient::cpu() {
                    Ok(r) => r,
                    Err(e) => {
                        crate::log_error!("device {id}: PJRT client failed: {e:#}");
                        return;
                    }
                };
                let mut cache = ResidentCache::new(cfg.cache_size, cfg.mem_budget, cfg.cost);
                let mut local = DeviceStats::default();
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Shutdown => break,
                        Msg::Stats(reply) => {
                            local.client = runtime.stats.clone();
                            let _ = reply.send(local.clone());
                        }
                        Msg::Run(job) => {
                            let _serial = cfg.serialize.as_ref().map(|l| l.lock().unwrap());
                            let t0 = Instant::now();
                            let mut ctx = DeviceCtx {
                                device_id: id,
                                runtime: &mut runtime,
                                cache: &mut cache,
                                host: &host,
                                stats: &mut local,
                                trace: &trace,
                            };
                            // Contain panics here so a faulty job cannot
                            // kill the stream (NEL-submitted jobs catch
                            // their own panics; raw submit()/run_blocking
                            // jobs would otherwise take the worker — and
                            // its accumulated stats — down with them).
                            let caught = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| job(&mut ctx)),
                            );
                            if caught.is_err() {
                                crate::log_error!("device {id}: compute job panicked");
                            }
                            local.jobs += 1;
                            local.busy_secs += t0.elapsed().as_secs_f64();
                        }
                    }
                }
                // residual resident copies just drop here; host store sync
                // is handled by explicit drains
            })
            .map_err(|e| anyhow!("spawning device {id}: {e}"))?;
        Ok(DeviceHandle { id, tx, join: Some(join) })
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn device(&self, id: usize) -> &DeviceHandle {
        &self.devices[id]
    }

    pub fn stats(&self) -> Vec<DeviceStats> {
        self.devices.iter().map(|d| d.stats()).collect()
    }

    /// Submit a job and block until it completes, returning its value.
    /// Convenience for tests and sequential baselines.
    pub fn run_blocking<T, F>(&self, device: usize, f: F) -> Result<T>
    where
        T: Send + 'static,
        F: FnOnce(&mut DeviceCtx<'_>) -> Result<T> + Send + 'static,
    {
        let (tx, rx) = channel();
        self.device(device).submit(Box::new(move |ctx| {
            let _ = tx.send(f(ctx));
        }))?;
        rx.recv().map_err(|_| anyhow!("device {device} dropped the job"))?
    }
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        for d in &self.devices {
            let _ = d.tx.send(Msg::Shutdown);
        }
        for d in &mut self.devices {
            if let Some(j) = d.join.take() {
                let _ = j.join();
            }
        }
    }
}
