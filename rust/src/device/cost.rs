//! Transfer cost model (DESIGN.md §Hardware-Adaptation).
//!
//! The paper's cross-device message passing pays PCIe; same-device passing
//! is free. Swaps in/out of the active set pay host<->device bandwidth.
//! Here real copies already happen (honest relative costs on a CPU host);
//! the model *additionally* accumulates a virtual clock from a configurable
//! bandwidth + latency, so EXPERIMENTS.md can report what the schedule
//! would cost on PCIe-class links. `simulate = true` turns the virtual cost
//! into actual sleeps for end-to-end what-if runs.

use std::time::Duration;

use crate::device::stats::DeviceStats;

#[derive(Debug, Clone)]
pub struct CostModel {
    /// Host<->device bandwidth for swaps (bytes/sec). None = don't model.
    pub swap_bw: Option<f64>,
    /// Device<->device bandwidth for views/transfers (bytes/sec).
    pub transfer_bw: Option<f64>,
    /// Fixed per-operation latency.
    pub latency: Duration,
    /// If true, sleep for the modeled duration (otherwise account only).
    pub simulate: bool,
}

impl Default for CostModel {
    fn default() -> Self {
        // Account-only defaults sized like PCIe 4.0 x16 (~24 GB/s effective)
        // with a 10 us launch latency.
        CostModel {
            swap_bw: Some(24e9),
            transfer_bw: Some(24e9),
            latency: Duration::from_micros(10),
            simulate: false,
        }
    }
}

impl CostModel {
    /// No modeling at all (unit tests).
    pub fn free() -> CostModel {
        CostModel { swap_bw: None, transfer_bw: None, latency: Duration::ZERO, simulate: false }
    }

    fn model(&self, bytes: usize, bw: Option<f64>) -> f64 {
        match bw {
            None => 0.0,
            Some(bw) => self.latency.as_secs_f64() + bytes as f64 / bw,
        }
    }

    pub fn charge_swap(&self, bytes: usize, stats: &mut DeviceStats) {
        let secs = self.model(bytes, self.swap_bw);
        stats.modeled_swap_secs += secs;
        self.maybe_sleep(secs);
    }

    pub fn charge_transfer(&self, bytes: usize, stats: &mut DeviceStats) {
        let secs = self.model(bytes, self.transfer_bw);
        stats.modeled_transfer_secs += secs;
        stats.transfer_bytes += bytes as u64;
        stats.transfers += 1;
        self.maybe_sleep(secs);
    }

    /// Charge `n` same-sized transfers at once (the broadcast fan-out
    /// path submits ONE job per destination device instead of one per
    /// message). Accounting is identical to `n` `charge_transfer` calls —
    /// per-operation latency included — only the job-dispatch overhead is
    /// amortized.
    pub fn charge_transfer_batch(&self, n: usize, bytes: usize, stats: &mut DeviceStats) {
        if n == 0 {
            return;
        }
        let secs = self.model(bytes, self.transfer_bw) * n as f64;
        stats.modeled_transfer_secs += secs;
        stats.transfer_bytes += (bytes * n) as u64;
        stats.transfers += n as u64;
        self.maybe_sleep(secs);
    }

    fn maybe_sleep(&self, secs: f64) {
        if self.simulate && secs > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_accumulates() {
        let m = CostModel {
            swap_bw: Some(1e9),
            transfer_bw: Some(2e9),
            latency: Duration::from_micros(5),
            simulate: false,
        };
        let mut st = DeviceStats::default();
        m.charge_swap(1_000_000, &mut st); // 5us + 1ms
        assert!((st.modeled_swap_secs - 0.001005).abs() < 1e-9);
        m.charge_transfer(2_000_000, &mut st); // 5us + 1ms
        assert!((st.modeled_transfer_secs - 0.001005).abs() < 1e-9);
        assert_eq!(st.transfer_bytes, 2_000_000);
    }

    #[test]
    fn batch_charge_equals_n_single_charges() {
        let m = CostModel {
            swap_bw: None,
            transfer_bw: Some(1e9),
            latency: Duration::from_micros(7),
            simulate: false,
        };
        let mut single = DeviceStats::default();
        for _ in 0..5 {
            m.charge_transfer(1000, &mut single);
        }
        let mut batched = DeviceStats::default();
        m.charge_transfer_batch(5, 1000, &mut batched);
        assert_eq!(batched.transfers, single.transfers);
        assert_eq!(batched.transfer_bytes, single.transfer_bytes);
        assert!((batched.modeled_transfer_secs - single.modeled_transfer_secs).abs() < 1e-12);
        m.charge_transfer_batch(0, 1000, &mut batched);
        assert_eq!(batched.transfers, 5);
    }

    #[test]
    fn free_model_is_zero() {
        let m = CostModel::free();
        let mut st = DeviceStats::default();
        m.charge_swap(1 << 30, &mut st);
        assert_eq!(st.modeled_swap_secs, 0.0);
    }
}
