//! Elastic-fabric integration tests (DESIGN.md §Elastic fabric): node
//! death, deterministic fault injection, and bit-checkable chain
//! migration. Hermetic — real TCP sockets on 127.0.0.1 ephemeral ports,
//! no artifacts, no PJRT.
//!
//! The acceptance bar:
//! * a 2-node TcpLoopback SGLD run whose node 1 is killed mid-run by a
//!   fault plan recovers via migration and finishes with BIT-IDENTICAL
//!   final params, reservoir samples, and per-step losses to an
//!   uninterrupted 1-node run;
//! * dead-link detection fails pending futures within `dead_after`
//!   instead of hanging `wait()`, passing through `Suspect` on the way;
//! * an exhausted `recover_rounds` budget fails loudly, naming the dead
//!   node — never a hang;
//! * a running heartbeat monitor never perturbs the data-path frame
//!   counters (a broadcast is still exactly ONE frame per node);
//! * `connect_with_backoff` survives refused connection attempts and
//!   gives up loudly when the peer never appears.
//!
//! The whole file needs the transport's fault hooks, which integration
//! tests only see under `--features faultinject` (cfg(test) does not
//! apply across the crate boundary).
#![cfg(feature = "faultinject")]

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use push::data::{synth, Batch, DataLoader};
use push::device::CostModel;
use push::infer::sgmcmc::{
    linear_native_model, SgMcmc, SgmcmcAlgo, SgmcmcConfig, Schedule,
};
use push::infer::Infer;
use push::particle::{PFuture, Value};
use push::pd::checkpoint::Checkpoint;
use push::pd::transport::fault::{self, FaultPlan};
use push::pd::transport::{spawn_loopback_node, NodeTransport, TcpNode};
use push::pd::wire::CreateSpec;
use push::pd::{FabricConfig, LinkHealth, SpecOpts, Topology, TransportKind};
use push::runtime::{Manifest, Tensor};
use push::util::rng::Rng;
use push::{NelConfig, Pid, PushDist};

const D: usize = 6;
const BATCH: usize = 8;

fn native_manifest() -> Manifest {
    push::infer::sgmcmc::linear_native_manifest(D, BATCH)
}

fn nel_cfg() -> NelConfig {
    NelConfig {
        num_devices: 2,
        cache_size: 4,
        cost: CostModel::free(),
        control_workers: 2,
        seed: 7,
        ..NelConfig::default()
    }
}

fn pd_with(nodes: usize, transport: TransportKind, fabric: &FabricConfig) -> PushDist {
    PushDist::with_topology_and_fabric(
        &native_manifest(),
        "linear_native",
        nel_cfg(),
        &Topology { nodes, transport },
        fabric,
    )
    .unwrap()
}

fn init_params(i: usize) -> Tensor {
    Tensor::f32(vec![D], Rng::new(0xBEEF).fold_in(i as u64).normal_vec(D))
}

fn chain_cfg(particles: usize, algo: SgmcmcAlgo, temperature: f32) -> SgmcmcConfig {
    SgmcmcConfig {
        particles,
        algo,
        schedule: Schedule::Constant { eps: 2e-2 },
        temperature,
        friction: 0.2,
        burn_in: 2,
        thin: 1,
        max_samples: 8,
        prior_std: None,
        seed: 21,
        model: linear_native_model(),
        init: Some(Arc::new(init_params)),
    }
}

fn fixed_batches(n_batches: usize, seed: u64) -> Vec<Batch> {
    let data = synth::linear(BATCH * n_batches, D, 0.05, seed);
    DataLoader::new(data, BATCH, false, 0).epoch()
}

// ---- bit-checkable chain migration ---------------------------------------

#[test]
fn node_death_recovers_bit_identically_to_uninterrupted_run() {
    let n = 4;
    let batches = fixed_batches(6, 11);
    let kill_step = 3; // post-burn-in: the reservoir already has content

    // control: an uninterrupted 1-node in-process run (T > 0 so the
    // deterministic noise streams are exercised too)
    let control = SgMcmc::new(
        pd_with(1, TransportKind::InProc, &FabricConfig::default()),
        chain_cfg(n, SgmcmcAlgo::Sgld, 1e-3),
    )
    .unwrap();
    let mut control_losses = Vec::new();
    for b in &batches {
        control_losses.push(control.step_all(&b.x, &b.y).unwrap());
    }
    let control_params = control.pd().drain_params().unwrap();

    // elastic: 2-node tcp run; a fault plan kills node 1's link on its
    // next data frame — i.e. deterministically at round `kill_step`
    let pd = pd_with(2, TransportKind::TcpLoopback, &FabricConfig::default());
    let addr = pd.peer_addr(1).expect("node 1 is a wire link");
    let algo =
        SgMcmc::new(pd, chain_cfg(n, SgmcmcAlgo::Sgld, 1e-3)).unwrap().with_recovery(1);
    let mut ckpt = Checkpoint::capture(algo.pd()).unwrap();
    let mut used = 0usize;
    let mut losses = Vec::new();
    for (i, b) in batches.iter().enumerate() {
        if i == kill_step {
            fault::set_plan(
                addr,
                FaultPlan { drop_after_frames: Some(0), ..FaultPlan::default() },
            );
        }
        losses.push(algo.step_all_recovering(&b.x, &b.y, &mut ckpt, &mut used).unwrap());
    }
    fault::clear(addr);

    assert_eq!(used, 1, "exactly one recovery round");
    assert_eq!(algo.pd().dead_nodes(), vec![1]);
    // the dead node's particles (round-robin: pids 1 and 3) moved to node 0
    assert_eq!(algo.pd().node_of(Pid(1)), Some(0), "pid 1 not migrated");
    assert_eq!(algo.pd().node_of(Pid(3)), Some(0), "pid 3 not migrated");

    // BIT-IDENTICAL: per-step losses, final params, reservoirs
    assert_eq!(losses, control_losses, "per-step losses diverged across the kill");
    let params: BTreeMap<Pid, Tensor> = algo.pd().drain_params().unwrap();
    assert_eq!(params.len(), n);
    for (pid, want) in &control_params {
        assert_eq!(&params[pid], want, "{pid} params diverged after migration");
    }
    for pid in control.pids() {
        let a = control.chain(pid);
        let b = algo.chain(pid);
        assert_eq!(a.step, b.step, "{pid} chain clock diverged");
        assert_eq!(a.seen, b.seen, "{pid} reservoir candidate count diverged");
        assert_eq!(a.samples, b.samples, "{pid} reservoir samples diverged");
    }
}

#[test]
fn node_death_recovers_bit_identically_on_the_evented_fabric() {
    // Same kill/migrate round as above, but over TcpLoopbackEvented: the
    // fault plan still fires in the shared `request_inner`, and the
    // severing runs through the reactor's EOF path instead of a reader
    // thread's exit. Recovery must be byte-for-byte the same story.
    let n = 4;
    let batches = fixed_batches(5, 23);
    let kill_step = 2;

    let control = SgMcmc::new(
        pd_with(1, TransportKind::InProc, &FabricConfig::default()),
        chain_cfg(n, SgmcmcAlgo::Sgld, 1e-3),
    )
    .unwrap();
    let mut control_losses = Vec::new();
    for b in &batches {
        control_losses.push(control.step_all(&b.x, &b.y).unwrap());
    }
    let control_params = control.pd().drain_params().unwrap();

    let pd = pd_with(2, TransportKind::TcpLoopbackEvented, &FabricConfig::default());
    let addr = pd.peer_addr(1).expect("node 1 is a wire link");
    let algo =
        SgMcmc::new(pd, chain_cfg(n, SgmcmcAlgo::Sgld, 1e-3)).unwrap().with_recovery(1);
    let mut ckpt = Checkpoint::capture(algo.pd()).unwrap();
    let mut used = 0usize;
    let mut losses = Vec::new();
    for (i, b) in batches.iter().enumerate() {
        if i == kill_step {
            fault::set_plan(
                addr,
                FaultPlan { drop_after_frames: Some(0), ..FaultPlan::default() },
            );
        }
        losses.push(algo.step_all_recovering(&b.x, &b.y, &mut ckpt, &mut used).unwrap());
    }
    fault::clear(addr);

    assert_eq!(used, 1, "exactly one recovery round");
    assert_eq!(algo.pd().dead_nodes(), vec![1]);
    assert_eq!(algo.pd().node_of(Pid(1)), Some(0), "pid 1 not migrated");
    assert_eq!(algo.pd().node_of(Pid(3)), Some(0), "pid 3 not migrated");
    assert_eq!(losses, control_losses, "per-step losses diverged across the evented kill");
    let params: BTreeMap<Pid, Tensor> = algo.pd().drain_params().unwrap();
    assert_eq!(params.len(), n);
    for (pid, want) in &control_params {
        assert_eq!(&params[pid], want, "{pid} params diverged after evented migration");
    }
}

// ---- dead-link detection --------------------------------------------------

#[test]
fn dead_link_detection_fails_pending_futures_within_dead_after() {
    // A peer that accepts (kernel backlog) but never speaks the protocol:
    // no pongs, no responses — the silent-death shape heartbeats exist for.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let node = TcpNode::connect(addr).unwrap();
    let dead_after = Duration::from_millis(300);

    let fut = node.send(Pid(0), "PING", vec![]);
    let t0 = Instant::now();
    let mut saw_suspect = false;
    // hand-driven monitor ticks (the fabric's thread does exactly this)
    loop {
        match node.heartbeat_tick(dead_after) {
            LinkHealth::Dead => break,
            LinkHealth::Suspect => saw_suspect = true,
            LinkHealth::Healthy => {}
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "monitor never declared the silent link dead"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    assert!(t0.elapsed() >= dead_after, "declared dead before the silence threshold");
    assert!(saw_suspect, "Suspect must precede Dead on a silent link");

    // severing the link failed the pending future promptly — no hang
    let err = fut.wait().unwrap_err();
    assert!(err.msg.contains("connection closed"), "{err}");
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "pending future took {:?} to fail",
        t0.elapsed()
    );
    assert_eq!(node.health(), LinkHealth::Dead);
    assert!(node.counters().errors >= 1, "link failures must be counted");
}

// ---- bounded recovery -----------------------------------------------------

#[test]
fn exhausted_recover_budget_fails_loudly_naming_the_dead_node() {
    let batches = fixed_batches(1, 17);
    let pd = pd_with(2, TransportKind::TcpLoopback, &FabricConfig::default());
    let addr = pd.peer_addr(1).unwrap();
    // budget 0: the first node death must fail the run, not hang it
    let algo = SgMcmc::new(pd, chain_cfg(2, SgmcmcAlgo::Sgld, 0.0)).unwrap();
    let mut ckpt = Checkpoint::capture(algo.pd()).unwrap();
    let mut used = 0usize;
    fault::set_plan(addr, FaultPlan { drop_after_frames: Some(0), ..FaultPlan::default() });
    let err = algo
        .step_all_recovering(&batches[0].x, &batches[0].y, &mut ckpt, &mut used)
        .unwrap_err();
    fault::clear(addr);
    let msg = format!("{err:#}");
    assert!(msg.contains("recover budget (0)"), "budget not named: {msg}");
    assert!(msg.contains("node 1"), "dead node not named: {msg}");
    assert!(msg.contains(&addr.to_string()), "dead node address not named: {msg}");
}

// ---- heartbeats stay off the data path ------------------------------------

#[test]
fn heartbeat_monitor_does_not_perturb_data_path_counters() {
    let fabric = FabricConfig {
        heartbeat_every: Some(Duration::from_millis(2)),
        dead_after: Duration::from_millis(500),
    };
    let pd = pd_with(2, TransportKind::TcpLoopback, &fabric);
    let pids = pd
        .p_create_spec_n(6, |_| SpecOpts {
            program: Some(("echo".to_string(), Value::Unit)),
            no_params: true,
            ..SpecOpts::default()
        })
        .unwrap();
    // let a burst of probes flow before measuring the data path
    std::thread::sleep(Duration::from_millis(80));

    let before = pd.transport_counters();
    let futs = pd.broadcast(&pids, "PING", vec![]);
    PFuture::join_all(&futs).wait().unwrap();
    let after = pd.transport_counters();
    for node in 0..2 {
        assert_eq!(
            after[node].frames_sent - before[node].frames_sent,
            1,
            "node {node}: heartbeat probes must not count as data frames"
        );
        assert_eq!(
            after[node].frames_received - before[node].frames_received,
            1,
            "node {node}: pongs must not count as data frames"
        );
        assert_eq!(after[node].errors, 0, "node {node}: healthy link reported errors");
    }
    // ...while the probes themselves ARE accounted, in their own counter
    for (i, c) in pd.transport_counters().iter().enumerate() {
        assert!(c.heartbeats > 0, "node {i}: monitor sent no probes");
    }
    assert!(
        pd.link_health().iter().all(|h| *h != LinkHealth::Dead),
        "healthy links declared dead: {:?}",
        pd.link_health()
    );
}

// ---- startup backoff ------------------------------------------------------

#[test]
fn connect_backoff_survives_refused_attempts() {
    let model = Arc::new(native_manifest().model("linear_native").unwrap().clone());
    let (addr, _server) = spawn_loopback_node(nel_cfg(), model).unwrap();
    // the first two connects are refused (a worker still binding its port)
    fault::set_plan(addr, FaultPlan { refuse_connects: 2, ..FaultPlan::default() });
    let node = TcpNode::connect_with_backoff(addr, 6).unwrap();
    fault::clear(addr);
    assert_eq!(node.peer_addr(), Some(addr));
    // the surviving link actually works
    let pid = node
        .create_spec(CreateSpec {
            pid: Pid(0),
            device: None,
            program: Some(("echo".to_string(), Value::Unit)),
            state: Vec::new(),
            no_params: true,
            init_params: None,
            model: "linear_native".to_string(),
        })
        .unwrap();
    assert_eq!(pid, Pid(0));
    assert_eq!(node.send(pid, "WHO", vec![]).wait().unwrap(), Value::Usize(0));
}

#[test]
fn connect_backoff_gives_up_loudly() {
    // bind a port and immediately free it: nothing ever listens there
    let addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let err = TcpNode::connect_with_backoff(addr, 2).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("after 2 attempts"), "{msg}");
    assert!(msg.contains(&addr.to_string()), "{msg}");
}
