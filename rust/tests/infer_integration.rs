//! Integration: the BDL algorithms (ensemble / multi-SWAG / SVGD) over real
//! artifacts, plus Push-vs-baseline consistency (paper §5.1's comparison).
//! Requires `make artifacts` and a `--features pjrt` build.
#![cfg(feature = "pjrt")]

use push::baselines::Baseline;
use push::bench::{data_for, Method};
use push::data::{synth, DataLoader};
use push::device::CostModel;
use push::infer::{
    svgd_update_native, DeepEnsemble, Infer, MultiSwag, Svgd, SvgdConfig, SwagConfig,
};
use push::runtime::{artifacts_dir, Manifest, Tensor};
use push::util::rng::Rng;
use push::{NelConfig, PushDist};

fn manifest() -> Manifest {
    Manifest::load(artifacts_dir()).expect("run `make artifacts` before cargo test")
}

fn cfg(devices: usize) -> NelConfig {
    NelConfig {
        num_devices: devices,
        cache_size: 8,
        cost: CostModel::free(),
        seed: 3,
        ..NelConfig::default()
    }
}

fn mlp_loader(m: &Manifest, batches: usize, seed: u64) -> DataLoader {
    let model = m.model("mlp_small").unwrap();
    let data = synth::linear(model.batch() * batches, model.x_shape[1], 0.05, seed);
    DataLoader::new(data, model.batch(), true, seed).with_max_batches(batches)
}

#[test]
fn ensemble_trains_and_learns() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_small", cfg(2)).unwrap();
    let mut algo = DeepEnsemble::new(pd, 4, 5e-3).unwrap();
    let mut loader = mlp_loader(&m, 6, 1);
    let report = algo.train(&mut loader, 8).unwrap();
    assert_eq!(report.epochs.len(), 8);
    let first = report.epochs[0].mean_loss;
    let last = report.final_loss();
    assert!(last < 0.5 * first, "ensemble failed to learn: {first} -> {last}");
    // posterior-mean prediction has the right shape
    let b = loader.epoch()[0].clone();
    let pred = algo.predict_mean(&b.x).unwrap();
    assert_eq!(pred.element_count(), b.y.element_count());
}

#[test]
fn multiswag_moments_track_trajectory() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_small", cfg(2)).unwrap();
    let mut algo = MultiSwag::new(
        pd,
        SwagConfig {
            particles: 3,
            lr: 5e-3,
            pretrain_epochs: 2,
            n_samples: 4,
            scale: 1e-3,
            adam: false,
            seed: 5,
        },
    )
    .unwrap();
    let mut loader = mlp_loader(&m, 4, 2);
    let report = algo.train(&mut loader, 6).unwrap();
    assert!(report.final_loss() < report.epochs[0].mean_loss);
    // regress task: SWAG prediction averages posterior draws
    let b = loader.epoch()[0].clone();
    let pred = algo.predict_swag(&b.x).unwrap();
    assert_eq!(pred.element_count(), b.y.element_count());
    assert!(pred.as_f32().iter().all(|v| v.is_finite()));
}

#[test]
fn svgd_artifact_and_native_agree_end_to_end() {
    // Two SVGD runs — Pallas artifact kernel vs native fallback — must
    // produce (nearly) identical parameters given identical seeds.
    let m = manifest();
    let run = |force_native: bool| -> Vec<Tensor> {
        let pd = PushDist::new(&m, "mlp_small", cfg(2)).unwrap();
        let mut algo = Svgd::new(
            pd,
            SvgdConfig {
                particles: 4,
                lr: 1e-3,
                lengthscale: 10.0,
                median_heuristic: false,
                prior_std: None,
                force_native,
            },
        )
        .unwrap();
        let mut loader = mlp_loader(&m, 3, 7);
        algo.train(&mut loader, 2).unwrap();
        let snap = algo.pd().drain_params().unwrap();
        snap.into_values().collect()
    };
    let with_artifact = run(false);
    let native = run(true);
    assert_eq!(with_artifact.len(), native.len());
    for (a, b) in with_artifact.iter().zip(&native) {
        let (av, bv) = (a.as_f32(), b.as_f32());
        let max_diff = av
            .iter()
            .zip(bv)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-4, "kernel vs native diverged: {max_diff}");
    }
}

#[test]
fn svgd_learns_regression() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_small", cfg(2)).unwrap();
    let mut algo = Svgd::new(
        pd,
        SvgdConfig { particles: 4, lr: 5e-3, lengthscale: 10.0, ..SvgdConfig::default() },
    )
    .unwrap();
    let mut loader = mlp_loader(&m, 5, 9);
    let report = algo.train(&mut loader, 8).unwrap();
    assert!(
        report.final_loss() < 0.6 * report.epochs[0].mean_loss,
        "svgd failed to learn: {} -> {}",
        report.epochs[0].mean_loss,
        report.final_loss()
    );
}

#[test]
fn svgd_single_particle_degenerates_to_sgd() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_small", cfg(1)).unwrap();
    let mut algo =
        Svgd::new(pd, SvgdConfig { particles: 1, lr: 5e-3, ..SvgdConfig::default() }).unwrap();
    let mut loader = mlp_loader(&m, 3, 11);
    let report = algo.train(&mut loader, 4).unwrap();
    assert!(report.final_loss() < report.epochs[0].mean_loss);
}

#[test]
fn push_matches_baseline_trajectories_ensemble() {
    // Same seeds => identical per-member parameter trajectories between
    // Push (1 device) and the handwritten sequential baseline.
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_small", cfg(1)).unwrap();
    let mut push_algo = DeepEnsemble::new(pd, 3, 1e-2).unwrap();
    let mut loader = mlp_loader(&m, 3, 21);
    push_algo.train(&mut loader, 2).unwrap();
    let push_params = push_algo.pd().drain_params().unwrap();

    let mut base = Baseline::new(&m, "mlp_small", 3, 3).unwrap();
    let mut loader = mlp_loader(&m, 3, 21);
    base.train_ensemble(&mut loader, 2, 1e-2).unwrap();

    for (i, (_, pp)) in push_params.iter().enumerate() {
        let bp = &base.params[i];
        let max_diff = pp
            .as_f32()
            .iter()
            .zip(bp.as_f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-5, "member {i} diverged from baseline: {max_diff}");
    }
}

#[test]
fn baseline_svgd_agrees_with_push_svgd() {
    let m = manifest();
    // Push SVGD with native kernel (same math path as baseline)
    let pd = PushDist::new(&m, "mlp_small", cfg(1)).unwrap();
    let mut algo = Svgd::new(
        pd,
        SvgdConfig {
            particles: 3,
            lr: 1e-3,
            lengthscale: 10.0,
            median_heuristic: false,
            prior_std: None,
            force_native: true,
        },
    )
    .unwrap();
    let mut loader = mlp_loader(&m, 2, 31);
    algo.train(&mut loader, 1).unwrap();
    let push_params: Vec<Tensor> = algo.pd().drain_params().unwrap().into_values().collect();

    let mut base = Baseline::new(&m, "mlp_small", 3, 3).unwrap();
    let mut loader = mlp_loader(&m, 2, 31);
    base.train_svgd(&mut loader, 1, 1e-3, 10.0).unwrap();

    for (pp, bp) in push_params.iter().zip(&base.params) {
        let max_diff = pp
            .as_f32()
            .iter()
            .zip(bp.as_f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "push vs baseline svgd diverged: {max_diff}");
    }
}

#[test]
fn native_svgd_matches_pallas_artifact_directly() {
    // Direct kernel-level consistency: random stacked inputs through the
    // AOT artifact vs the native Rust implementation.
    let m = manifest();
    let d = m.model("mlp_small").unwrap().param_count;
    let spec = m.svgd_for(4, d).expect("svgd artifact n=4 for mlp_small");
    let mut rng = Rng::new(17);
    let rows: Vec<Tensor> = (0..4).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
    let grows: Vec<Tensor> = (0..4).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
    let h = 25.0f32;

    let native = svgd_update_native(&rows, &grows, h).unwrap();

    let mut client = push::runtime::RuntimeClient::cpu().unwrap();
    let refs: Vec<&Tensor> = rows.iter().collect();
    let grefs: Vec<&Tensor> = grows.iter().collect();
    let outs = client
        .execute(
            &spec.file,
            &[
                Tensor::stack_rows(&refs),
                Tensor::stack_rows(&grefs),
                Tensor::scalar_f32(h),
            ],
        )
        .unwrap();
    let kernel_rows = outs[0].unstack_rows();
    for (a, b) in native.iter().zip(&kernel_rows) {
        let max_diff = a
            .as_f32()
            .iter()
            .zip(b.as_f32())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "native vs pallas kernel: {max_diff}");
    }
}

#[test]
fn data_for_covers_all_archs() {
    let m = manifest();
    for name in ["vit_fig4", "cgcnn_fig4", "unet_fig4", "resnet_fig7", "schnet_fig7", "mlp_small"]
    {
        let model = m.model(name).unwrap();
        let ds = data_for(model, model.batch() * 2, 1).unwrap();
        assert_eq!(ds.n, model.batch() * 2, "{name}");
        let _ = Method::all();
    }
}
