//! Distributed-NEL integration tests (hermetic: real TCP sockets on
//! 127.0.0.1 ephemeral ports, no artifacts, no PJRT).
//!
//! The acceptance bar of the transport refactor:
//! * a 2-node `TcpLoopback` SGLD(T=0) run produces EXACTLY the final
//!   parameters of the 1-node in-process run — deterministic streams are
//!   keyed by (seed, GLOBAL pid, step), never by node or placement;
//! * a cross-node `broadcast` puts ONE frame on the wire per destination
//!   node, whatever the fan-out;
//! * `PFuture::join_all` error ordering (first error by INPUT position)
//!   survives the wire;
//! * checkpoints capture through a TCP fabric and restore into an
//!   in-process one (the shared Value codec is the seam);
//! * closure-based creation is cleanly rejected on wire transports, and
//!   node-local NELs name the node when asked about remote pids.

use std::collections::BTreeMap;
use std::sync::Arc;

use push::data::{synth, Batch, DataLoader};
use push::device::CostModel;
use push::infer::sgmcmc::{
    linear_native_model, SgMcmc, SgmcmcAlgo, SgmcmcConfig, Schedule,
};
use push::infer::Infer;
use push::nel::CreateOpts;
use push::particle::{handler, PFuture, Value};
use push::pd::checkpoint::Checkpoint;
use push::pd::{SpecOpts, Topology, TransportKind};
use push::runtime::{Manifest, Tensor};
use push::util::rng::Rng;
use push::{NelConfig, Pid, PushDist};

const D: usize = 6;
const BATCH: usize = 8;

fn native_manifest() -> Manifest {
    push::infer::sgmcmc::linear_native_manifest(D, BATCH)
}

fn pd_with(nodes: usize, transport: TransportKind) -> PushDist {
    let cfg = NelConfig {
        num_devices: 2,
        cache_size: 4,
        cost: CostModel::free(),
        control_workers: 2,
        seed: 7,
        ..NelConfig::default()
    };
    PushDist::with_topology(
        &native_manifest(),
        "linear_native",
        cfg,
        &Topology { nodes, transport },
    )
    .unwrap()
}

fn init_params(i: usize) -> Tensor {
    Tensor::f32(vec![D], Rng::new(0xBEEF).fold_in(i as u64).normal_vec(D))
}

fn chain_cfg(particles: usize, algo: SgmcmcAlgo, temperature: f32) -> SgmcmcConfig {
    SgmcmcConfig {
        particles,
        algo,
        schedule: Schedule::Constant { eps: 2e-2 },
        temperature,
        friction: 0.2,
        burn_in: 2,
        thin: 1,
        max_samples: 8,
        prior_std: None,
        seed: 21,
        model: linear_native_model(),
        init: Some(Arc::new(init_params)),
    }
}

fn fixed_batches(n_batches: usize, seed: u64) -> Vec<Batch> {
    let data = synth::linear(BATCH * n_batches, D, 0.05, seed);
    DataLoader::new(data, BATCH, false, 0).epoch()
}

fn echo_particles(pd: &PushDist, n: usize) -> Vec<Pid> {
    pd.p_create_spec_n(n, |_| SpecOpts {
        program: Some(("echo".to_string(), Value::Unit)),
        no_params: true,
        ..SpecOpts::default()
    })
    .unwrap()
}

// ---- determinism across placements --------------------------------------

#[test]
fn two_node_tcp_sgld_matches_single_node_inproc_exactly() {
    let n = 4;
    let batches = fixed_batches(6, 11);

    let run = |pd: PushDist| -> BTreeMap<Pid, Tensor> {
        let algo = SgMcmc::new(pd, chain_cfg(n, SgmcmcAlgo::Sgld, 0.0)).unwrap();
        for b in &batches {
            algo.step_all(&b.x, &b.y).unwrap();
        }
        algo.pd().drain_params().unwrap()
    };

    let local = run(pd_with(1, TransportKind::InProc));
    let tcp = run(pd_with(2, TransportKind::TcpLoopback));
    let inproc2 = run(pd_with(2, TransportKind::InProc));

    assert_eq!(local.len(), n);
    assert_eq!(tcp.len(), n);
    for (pid, want) in &local {
        // EXACT equality: same (seed, pid, step) streams, same f32 ops,
        // different placement — bitwise identical results
        assert_eq!(&tcp[pid], want, "{pid} diverged across the tcp fabric");
        assert_eq!(&inproc2[pid], want, "{pid} diverged across 2 inproc nodes");
    }
}

#[test]
fn two_node_tcp_sghmc_with_noise_is_placement_invariant() {
    // temperature > 0 exercises the noise stream keying as well
    let n = 3;
    let batches = fixed_batches(5, 12);
    let run = |pd: PushDist| -> BTreeMap<Pid, Tensor> {
        let algo = SgMcmc::new(pd, chain_cfg(n, SgmcmcAlgo::Sghmc, 1e-3)).unwrap();
        for b in &batches {
            algo.step_all(&b.x, &b.y).unwrap();
        }
        algo.pd().drain_params().unwrap()
    };
    let local = run(pd_with(1, TransportKind::InProc));
    let tcp = run(pd_with(2, TransportKind::TcpLoopback));
    for (pid, want) in &local {
        assert_eq!(&tcp[pid], want, "{pid} noise stream depends on placement");
    }
}

#[test]
fn two_node_tcp_mlp_native_sgld_matches_single_node() {
    // same bar as the linear test, but through the registered-model seam:
    // "mlp_native" crosses the wire as a NAME and every node rebuilds the
    // closed-form MLP grad/forward closures locally via the registry
    let n = 3;
    let nm = push::infer::native_model("mlp_native").unwrap();
    let bsz = nm.spec.batch();
    let data = synth::spiral(bsz * 4, 1.5, 0.02, 31);
    let batches = DataLoader::new(data, bsz, false, 0).epoch();

    let run = |nodes: usize, transport: TransportKind| -> BTreeMap<Pid, Tensor> {
        let cfg = NelConfig {
            num_devices: 2,
            cache_size: 4,
            cost: CostModel::free(),
            control_workers: 2,
            seed: 7,
            ..NelConfig::default()
        };
        let pd = PushDist::with_topology(
            &push::infer::native_manifest(),
            "mlp_native",
            cfg,
            &Topology { nodes, transport },
        )
        .unwrap();
        let algo = SgMcmc::new(
            pd,
            SgmcmcConfig {
                particles: n,
                algo: SgmcmcAlgo::Sgld,
                schedule: Schedule::Constant { eps: 5e-2 },
                temperature: 0.0,
                friction: 0.2,
                burn_in: 1,
                thin: 1,
                max_samples: 8,
                prior_std: Some(10.0),
                seed: 33,
                model: nm.source.clone(),
                init: Some(nm.seeded_init(77)),
            },
        )
        .unwrap();
        for b in &batches {
            algo.step_all(&b.x, &b.y).unwrap();
        }
        algo.pd().drain_params().unwrap()
    };

    let local = run(1, TransportKind::InProc);
    let tcp = run(2, TransportKind::TcpLoopback);
    assert_eq!(local.len(), n);
    for (pid, want) in &local {
        assert_eq!(&tcp[pid], want, "{pid}: mlp_native diverged across the tcp fabric");
    }
}

// ---- frame batching ------------------------------------------------------

#[test]
fn broadcast_sends_one_frame_per_destination_node() {
    let pd = pd_with(2, TransportKind::TcpLoopback);
    let pids = echo_particles(&pd, 6); // round-robin: 3 per node
    assert_eq!(pd.nodes(), 2);
    assert_eq!(pd.node_of(pids[0]), Some(0));
    assert_eq!(pd.node_of(pids[1]), Some(1));

    let before = pd.transport_counters();
    let futs = pd.broadcast(&pids, "PING", vec![]);
    assert_eq!(futs.len(), 6);
    PFuture::join_all(&futs).wait().unwrap();
    let after = pd.transport_counters();

    for node in 0..2 {
        let sent = after[node].frames_sent - before[node].frames_sent;
        assert_eq!(sent, 1, "node {node}: a 3-wide fan-out must be ONE request frame");
        let recvd = after[node].frames_received - before[node].frames_received;
        assert_eq!(recvd, 1, "node {node}: and ONE batched response frame");
    }

    // a second broadcast with a tensor payload behaves the same
    let before = pd.transport_counters();
    let futs = pd.broadcast(&pids, "PING", vec![Value::Tensor(Tensor::zeros(vec![16]))]);
    PFuture::join_all(&futs).wait().unwrap();
    let after = pd.transport_counters();
    for node in 0..2 {
        assert_eq!(after[node].frames_sent - before[node].frames_sent, 1);
        assert!(after[node].bytes_sent > before[node].bytes_sent);
    }
}

#[test]
fn inproc_fabric_puts_nothing_on_any_wire() {
    let pd = pd_with(2, TransportKind::InProc);
    let pids = echo_particles(&pd, 4);
    PFuture::join_all(&pd.broadcast(&pids, "PING", vec![])).wait().unwrap();
    for c in pd.transport_counters() {
        assert_eq!(c.frames_sent, 0);
        assert_eq!(c.frames_received, 0);
    }
}

// ---- error semantics across the wire -------------------------------------

#[test]
fn join_all_error_ordering_survives_the_wire() {
    let pd = pd_with(2, TransportKind::TcpLoopback);
    let pids = echo_particles(&pd, 4); // pid i on node i % 2

    // every target fails; the winning error must be the FIRST INPUT
    // position (pids[3], on node 1) no matter which node answers first
    let order = vec![pids[3], pids[0], pids[1], pids[2]];
    let futs = pd.broadcast(&order, "FAIL", vec![]);
    let err = PFuture::join_all(&futs).wait().unwrap_err();
    assert_eq!(err.msg, format!("echo FAIL on {}", pids[3]), "wrong error won");

    // mixed batch: per-position results, unknown pids error in slot
    let order = vec![pids[1], Pid(999), pids[2]];
    let futs = pd.broadcast(&order, "WHO", vec![]);
    assert_eq!(futs[0].wait().unwrap(), Value::Usize(pids[1].0 as usize));
    assert!(futs[1].wait().unwrap_err().msg.contains("unknown particle"));
    assert_eq!(futs[2].wait().unwrap(), Value::Usize(pids[2].0 as usize));
}

#[test]
fn send_and_direct_ops_route_to_the_owning_node() {
    let pd = pd_with(2, TransportKind::TcpLoopback);
    let pids = echo_particles(&pd, 4);
    for pid in &pids {
        assert_eq!(
            pd.p_launch(*pid, "WHO", vec![]).wait().unwrap(),
            Value::Usize(pid.0 as usize)
        );
    }
    // handler errors cross back as errors
    let err = pd.p_launch(pids[1], "FAIL", vec![]).wait().unwrap_err();
    assert!(err.msg.contains("echo FAIL"), "{err}");
    // a get on a no-params particle errors without wedging the link
    assert!(pd.get(pids[0]).wait().is_err());
    assert_eq!(
        pd.p_launch(pids[0], "WHO", vec![]).wait().unwrap(),
        Value::Usize(pids[0].0 as usize)
    );
}

// ---- checkpointing through the fabric ------------------------------------

#[test]
fn checkpoint_captures_over_tcp_and_restores_in_process() {
    let n = 3;
    let first = fixed_batches(4, 13);
    let second = fixed_batches(3, 14);

    let original =
        SgMcmc::new(pd_with(2, TransportKind::TcpLoopback), chain_cfg(n, SgmcmcAlgo::Sghmc, 1e-3))
            .unwrap();
    for b in &first {
        original.step_all(&b.x, &b.y).unwrap();
    }
    // capture drains every node over the wire, state included
    let ck = Checkpoint::capture(original.pd()).unwrap();
    assert_eq!(ck.params.len(), n);
    for pid in original.pids() {
        assert!(ck.state.contains_key(&pid), "{pid} chain state missing");
    }

    // file round-trip, then restore into a fresh IN-PROCESS fabric: the
    // shared codec is the seam, so transports are interchangeable
    let dir = std::env::temp_dir().join(format!("push-transport-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fabric.ckpt");
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(ck, loaded);
    std::fs::remove_dir_all(&dir).ok();

    let restored =
        SgMcmc::new(pd_with(1, TransportKind::InProc), chain_cfg(n, SgmcmcAlgo::Sghmc, 1e-3))
            .unwrap();
    loaded.restore(restored.pd()).unwrap();
    for b in &second {
        original.step_all(&b.x, &b.y).unwrap();
        restored.step_all(&b.x, &b.y).unwrap();
    }
    let a = original.pd().drain_params().unwrap();
    let b = restored.pd().drain_params().unwrap();
    for (pid, pa) in &a {
        assert_eq!(pa, &b[pid], "{pid} diverged after cross-transport restore");
    }
}

// ---- seam guard rails ----------------------------------------------------

#[test]
fn closure_creation_rejected_on_wire_transports() {
    let pd = pd_with(2, TransportKind::TcpLoopback);
    let noop = handler(|_ctx, _| Ok(Value::Unit));
    let err = pd
        .p_create(CreateOpts {
            no_params: true,
            receive: [("PING".to_string(), noop)].into_iter().collect(),
            ..CreateOpts::default()
        })
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("cannot cross the wire"),
        "unexpected error: {err:#}"
    );
}

#[test]
fn unknown_program_errors_cleanly_across_the_wire() {
    let pd = pd_with(2, TransportKind::TcpLoopback);
    let err = pd
        .p_create_spec(SpecOpts {
            program: Some(("no_such_program".to_string(), Value::Unit)),
            no_params: true,
            ..SpecOpts::default()
        })
        .unwrap_err();
    assert!(format!("{err:#}").contains("unknown handler program"), "{err:#}");
    // the link stays usable afterwards
    let pids = echo_particles(&pd, 2);
    assert_eq!(
        pd.p_launch(pids[1], "WHO", vec![]).wait().unwrap(),
        Value::Usize(pids[1].0 as usize)
    );
}

#[test]
fn model_mismatch_rejected_at_creation() {
    use push::pd::transport::{spawn_loopback_node, NodeTransport, TcpNode};
    use push::pd::wire::CreateSpec;
    let model = Arc::new(native_manifest().model("linear_native").unwrap().clone());
    let cfg = NelConfig {
        cost: CostModel::free(),
        control_workers: 2,
        ..NelConfig::default()
    };
    let (addr, _server) = spawn_loopback_node(cfg, model).unwrap();
    let node = TcpNode::connect(addr).unwrap();
    // a client training a different model must fail AT CREATION with a
    // clear handshake error, not as a shape error deep inside the NEL
    let err = node
        .create_spec(CreateSpec {
            pid: Pid(0),
            device: None,
            program: None,
            state: Vec::new(),
            no_params: true,
            init_params: None,
            model: "some_other_model".to_string(),
        })
        .unwrap_err();
    assert!(err.msg.contains("model mismatch"), "{err}");
}

#[test]
fn node_local_nel_names_the_node_for_remote_pids() {
    let pd = pd_with(2, TransportKind::InProc);
    let pids = echo_particles(&pd, 2); // pid 0 on node 0, pid 1 on node 1
    // node 0's NEL knows nothing about pid 1: handler-side sends to
    // remote pids must fail with a routing explanation
    let err = pd.nel().send(None, pids[1], "PING", vec![]).wait().unwrap_err();
    assert!(err.msg.contains("node 0"), "{err}");
    assert!(err.msg.contains("fabric"), "{err}");
    // ...while the fabric routes it fine
    assert!(pd.p_launch(pids[1], "PING", vec![]).wait().is_ok());
}

// ---- evented transport parity --------------------------------------------
//
// The evented flavor multiplexes every link onto the shared poll reactor
// instead of a reader-thread/writer-thread pair per connection. It must be
// observationally identical to the threaded reference: same bits out of a
// training run, same wire accounting, same failure detection, and a server
// that holds many concurrent connections where `serve_one` held one.

#[test]
fn two_node_evented_sgld_matches_threaded_and_inproc_exactly() {
    // temperature > 0 exercises the per-(seed, pid, step) noise streams too
    let n = 4;
    let batches = fixed_batches(6, 11);
    let run = |pd: PushDist| -> BTreeMap<Pid, Tensor> {
        let algo = SgMcmc::new(pd, chain_cfg(n, SgmcmcAlgo::Sgld, 1e-3)).unwrap();
        for b in &batches {
            algo.step_all(&b.x, &b.y).unwrap();
        }
        algo.pd().drain_params().unwrap()
    };
    let local = run(pd_with(1, TransportKind::InProc));
    let threaded = run(pd_with(2, TransportKind::TcpLoopback));
    let evented = run(pd_with(2, TransportKind::TcpLoopbackEvented));
    assert_eq!(local.len(), n);
    for (pid, want) in &local {
        assert_eq!(&threaded[pid], want, "{pid} diverged on the threaded fabric");
        assert_eq!(&evented[pid], want, "{pid} diverged on the evented fabric");
    }
}

#[test]
fn evented_broadcast_counters_match_threaded_exactly() {
    // one frame per destination node, and byte-for-byte the same wire
    // accounting as the threaded flavor — the batching seam is shared
    let measure = |transport: TransportKind| {
        let pd = pd_with(2, transport);
        let pids = echo_particles(&pd, 6); // round-robin: 3 per node
        let before = pd.transport_counters();
        let futs =
            pd.broadcast(&pids, "PING", vec![Value::Tensor(Tensor::zeros(vec![16]))]);
        PFuture::join_all(&futs).wait().unwrap();
        let after = pd.transport_counters();
        (0..2)
            .map(|node| {
                (
                    after[node].frames_sent - before[node].frames_sent,
                    after[node].frames_received - before[node].frames_received,
                    after[node].bytes_sent - before[node].bytes_sent,
                    after[node].bytes_received - before[node].bytes_received,
                )
            })
            .collect::<Vec<_>>()
    };
    let threaded = measure(TransportKind::TcpLoopback);
    let evented = measure(TransportKind::TcpLoopbackEvented);
    for node in 0..2 {
        assert_eq!(evented[node].0, 1, "node {node}: fan-out must stay ONE request frame");
        assert_eq!(evented[node].1, 1, "node {node}: and ONE batched response frame");
    }
    assert_eq!(threaded, evented, "wire accounting must be flavor-invariant");
}

#[test]
fn evented_mute_peer_heartbeat_severs_suspect_then_dead() {
    // Same silent-death shape as the elastic suite's threaded test, but the
    // severing now runs through the reactor's EOF path instead of a reader
    // thread's exit path.
    use push::pd::transport::{NodeTransport, TcpNode};
    use push::pd::LinkHealth;
    use std::time::{Duration, Instant};

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let node = TcpNode::connect_evented(addr).unwrap();
    assert_eq!(node.kind(), "tcp-evented");
    let dead_after = Duration::from_millis(300);

    let fut = node.send(Pid(0), "PING", vec![]);
    let t0 = Instant::now();
    let mut saw_suspect = false;
    loop {
        match node.heartbeat_tick(dead_after) {
            LinkHealth::Dead => break,
            LinkHealth::Suspect => saw_suspect = true,
            LinkHealth::Healthy => {}
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "monitor never declared the silent evented link dead"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    assert!(t0.elapsed() >= dead_after, "declared dead before the silence threshold");
    assert!(saw_suspect, "Suspect must precede Dead on a silent link");

    // the reactor's on_close drained the pending future — no hang
    let err = fut.wait().unwrap_err();
    assert!(err.msg.contains("connection closed"), "{err}");
    assert_eq!(node.health(), LinkHealth::Dead);
    assert!(node.counters().errors >= 1, "link failures must be counted");
}

#[test]
fn evented_server_holds_64_concurrent_connections() {
    // `serve_one` accepted exactly one connection; the evented accept loop
    // must hold N live links at once, each with its own lazily-built NEL.
    use push::pd::transport::{spawn_loopback_node_evented, NodeTransport, TcpNode};
    use push::pd::wire::CreateSpec;

    let model = Arc::new(native_manifest().model("linear_native").unwrap().clone());
    let cfg = NelConfig {
        num_devices: 1,
        cache_size: 2,
        cost: CostModel::free(),
        control_workers: 1,
        ..NelConfig::default()
    };
    let addr = spawn_loopback_node_evented(cfg, model).unwrap();
    let nodes: Vec<TcpNode> =
        (0..64).map(|_| TcpNode::connect_evented(addr).unwrap()).collect();

    // every link creates a particle while all 64 connections are open
    for (i, node) in nodes.iter().enumerate() {
        let pid = node
            .create_spec(CreateSpec {
                pid: Pid(i as u32),
                device: None,
                program: Some(("echo".to_string(), Value::Unit)),
                state: Vec::new(),
                no_params: true,
                init_params: None,
                model: "linear_native".to_string(),
            })
            .unwrap();
        assert_eq!(pid, Pid(i as u32));
    }
    // ...and round-trips concurrently: launch all 64 before waiting on any
    let futs: Vec<PFuture> = nodes
        .iter()
        .enumerate()
        .map(|(i, node)| node.send(Pid(i as u32), "WHO", vec![]))
        .collect();
    for (i, fut) in futs.into_iter().enumerate() {
        assert_eq!(fut.wait().unwrap(), Value::Usize(i), "connection {i} lost its answer");
    }
}

#[test]
fn evented_large_frames_and_heartbeats_survive_busy_shared_shards() {
    // Regression: the evented server used to WRITE responses inline on the
    // reactor shard thread and run dispatch there too. With client and
    // server halves sharing the same 4-shard reactor (the loopback shape),
    // a multi-megabyte snapshot response could park a shard in
    // poll(POLLOUT) against a peer only that same shard could drain —
    // permanent deadlock — and inline dispatch starved heartbeat pongs for
    // every other connection on the shard. Eight concurrent 4 MB snapshots
    // (2x the shard count, so both halves of some pair share a shard) must
    // all complete, while a bystander link's heartbeats stay Healthy
    // throughout.
    use push::pd::transport::{spawn_loopback_node_evented, NodeTransport, TcpNode};
    use push::pd::wire::CreateSpec;
    use push::pd::LinkHealth;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;

    const CONNS: usize = 8;
    const DIM: usize = 1 << 20; // 4 MB of f32 per snapshot frame

    let model = Arc::new(native_manifest().model("linear_native").unwrap().clone());
    let cfg = NelConfig {
        num_devices: 1,
        cache_size: 2,
        cost: CostModel::free(),
        control_workers: 1,
        ..NelConfig::default()
    };
    let addr = spawn_loopback_node_evented(cfg, model).unwrap();
    let nodes: Vec<TcpNode> =
        (0..CONNS).map(|_| TcpNode::connect_evented(addr).unwrap()).collect();
    let bystander = TcpNode::connect_evented(addr).unwrap();

    let blob = |i: usize| Tensor::f32(vec![DIM], vec![i as f32 + 0.5; DIM]);
    for (i, node) in nodes.iter().enumerate() {
        node.create_spec(CreateSpec {
            pid: Pid(i as u32),
            device: None,
            program: Some(("echo".to_string(), Value::Unit)),
            state: Vec::new(),
            no_params: true,
            init_params: None,
            model: "linear_native".to_string(),
        })
        .unwrap();
        node.restore_particle_state(
            Pid(i as u32),
            vec![("blob".to_string(), Value::Tensor(blob(i)))],
        )
        .unwrap();
    }

    // the bystander pings on a fabric-like cadence the whole time the big
    // frames are in flight; it must never be (falsely) declared dead
    let stop = Arc::new(AtomicBool::new(false));
    let saw_dead = Arc::new(AtomicBool::new(false));
    let ticker = {
        let stop = stop.clone();
        let saw_dead = saw_dead.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                if bystander.heartbeat_tick(Duration::from_millis(1500)) == LinkHealth::Dead
                {
                    saw_dead.store(true, Ordering::Release);
                    return;
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        })
    };

    // all 8 snapshots launched before any is waited on: responses land on
    // the shared reactor concurrently
    let futs: Vec<PFuture> = nodes
        .iter()
        .enumerate()
        .map(|(i, node)| node.snapshot_node(&[Pid(i as u32)]).remove(0))
        .collect();
    for (i, fut) in futs.into_iter().enumerate() {
        let got = fut
            .wait_timeout(Duration::from_secs(60))
            .expect("snapshot future hung — evented write path deadlocked a shard")
            .unwrap();
        let want = Value::List(vec![Value::List(vec![
            Value::Str("blob".to_string()),
            Value::Tensor(blob(i)),
        ])]);
        assert_eq!(got, want, "connection {i}: snapshot payload corrupted");
    }

    stop.store(true, Ordering::Release);
    ticker.join().unwrap();
    assert!(
        !saw_dead.load(Ordering::Acquire),
        "bystander link falsely severed while big frames were in flight"
    );
}

#[test]
fn fabric_stats_sum_each_node_exactly_once() {
    let pd = pd_with(2, TransportKind::TcpLoopback);
    let pids = echo_particles(&pd, 4);
    PFuture::join_all(&pd.broadcast(&pids, "PING", vec![])).wait().unwrap();
    PFuture::join_all(&pd.broadcast(&pids, "PING", vec![])).wait().unwrap();

    let per_node = pd.node_stats().unwrap();
    assert_eq!(per_node.len(), 2);
    let merged = pd.stats();
    assert_eq!(
        merged.msgs_sent,
        per_node.iter().map(|s| s.msgs_sent).sum::<u64>(),
        "merged messages must be the per-node sum (counted once)"
    );
    assert_eq!(merged.msgs_sent, 8, "4 particles x 2 rounds");
    assert_eq!(
        merged.devices.len(),
        per_node.iter().map(|s| s.devices.len()).sum::<usize>()
    );
    assert_eq!(
        merged.sched.handler_runs,
        per_node.iter().map(|s| s.sched.handler_runs).sum::<u64>()
    );
}
