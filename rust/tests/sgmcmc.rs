//! Hermetic SGMCMC integration tests (no artifacts, no PJRT): the native
//! linear ModelSource drives full particle-machinery chains — broadcast
//! fan-outs, device jobs, COW snapshots — so the deterministic properties
//! below hold on the default feature set.
//!
//! * SGLD at temperature 0 IS plain SGD: trajectories match a sequential
//!   reference loop bit-for-bit (and diverge once noise is on).
//! * SGHMC at temperature 0 is heavy-ball momentum SGD, and its momentum +
//!   chain clock + reservoir round-trip through pd::checkpoint (v2 state
//!   section), so a restored chain continues exactly where it left off.
//! * The bounded reservoir respects burn-in / thinning / capacity under a
//!   1024-particle stress round.

use std::sync::Arc;

use push::data::{synth, Batch, DataLoader};
use push::device::CostModel;
use push::infer::sgmcmc::{
    expected_candidates, linear_native_model, ModelSource, Schedule, SgMcmc, SgmcmcAlgo,
    SgmcmcConfig,
};
use push::infer::Infer;
use push::pd::checkpoint::Checkpoint;
use push::runtime::tensor::ops;
use push::runtime::{Manifest, Tensor};
use push::util::rng::Rng;
use push::{NelConfig, PushDist};

const D: usize = 6;
const BATCH: usize = 8;

fn native_manifest() -> Manifest {
    push::infer::sgmcmc::linear_native_manifest(D, BATCH)
}

fn pd(devices: usize, workers: usize) -> PushDist {
    let cfg = NelConfig {
        num_devices: devices,
        cache_size: 4,
        cost: CostModel::free(),
        control_workers: workers,
        seed: 7,
        ..NelConfig::default()
    };
    PushDist::new(&native_manifest(), "linear_native", cfg).unwrap()
}

fn init_params(i: usize) -> Tensor {
    Tensor::f32(vec![D], Rng::new(0xBEEF).fold_in(i as u64).normal_vec(D))
}

fn chain_cfg(particles: usize, algo: SgmcmcAlgo, temperature: f32) -> SgmcmcConfig {
    SgmcmcConfig {
        particles,
        algo,
        schedule: Schedule::Constant { eps: 2e-2 },
        temperature,
        friction: 0.2,
        burn_in: 3,
        thin: 2,
        max_samples: 4,
        prior_std: None,
        seed: 21,
        model: linear_native_model(),
        init: Some(Arc::new(init_params)),
    }
}

fn fixed_batches(n_batches: usize, seed: u64) -> Vec<Batch> {
    let data = synth::linear(BATCH * n_batches, D, 0.05, seed);
    DataLoader::new(data, BATCH, false, 0).epoch()
}

/// Native (loss, grad) closure used both by the chains and the reference
/// loops, so any divergence is in the particle machinery, not the math.
fn native_grad(params: &Tensor, x: &Tensor, y: &Tensor) -> Tensor {
    let ModelSource::Native { grad, .. } = linear_native_model() else { unreachable!() };
    grad(params, x, y).unwrap().1
}

fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    a.as_f32()
        .iter()
        .zip(b.as_f32())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[test]
fn sgld_zero_noise_matches_sgd_trajectory() {
    let n = 3;
    let eps = 2e-2f32;
    let batches = fixed_batches(5, 11);
    let algo = SgMcmc::new(pd(2, 2), chain_cfg(n, SgmcmcAlgo::Sgld, 0.0)).unwrap();
    for b in &batches {
        algo.step_all(&b.x, &b.y).unwrap();
    }
    let chained: Vec<Tensor> = algo.pd().drain_params().unwrap().into_values().collect();

    // sequential SGD reference: θ ← θ − ε ∇U(θ), same init, same batches
    let mut reference: Vec<Tensor> = (0..n).map(init_params).collect();
    for b in &batches {
        for p in reference.iter_mut() {
            let g = native_grad(p, &b.x, &b.y);
            ops::axpy(p, -eps, &g);
        }
    }
    assert_eq!(chained.len(), reference.len());
    for (i, (c, r)) in chained.iter().zip(&reference).enumerate() {
        let diff = max_abs_diff(c, r);
        assert!(diff < 1e-6, "chain {i} diverged from SGD: {diff}");
    }
}

#[test]
fn sgld_positive_temperature_injects_noise() {
    let batches = fixed_batches(3, 11);
    let noisy = SgMcmc::new(pd(1, 2), chain_cfg(2, SgmcmcAlgo::Sgld, 1e-2)).unwrap();
    let cold = SgMcmc::new(pd(1, 2), chain_cfg(2, SgmcmcAlgo::Sgld, 0.0)).unwrap();
    for b in &batches {
        noisy.step_all(&b.x, &b.y).unwrap();
        cold.step_all(&b.x, &b.y).unwrap();
    }
    let a: Vec<Tensor> = noisy.pd().drain_params().unwrap().into_values().collect();
    let b: Vec<Tensor> = cold.pd().drain_params().unwrap().into_values().collect();
    let moved = a.iter().zip(&b).any(|(x, y)| max_abs_diff(x, y) > 1e-7);
    assert!(moved, "temperature > 0 must perturb the trajectory");
}

#[test]
fn sghmc_zero_noise_is_heavy_ball_momentum() {
    let n = 2;
    let (eps, friction) = (2e-2f32, 0.2f32);
    let batches = fixed_batches(4, 12);
    let algo = SgMcmc::new(pd(2, 2), chain_cfg(n, SgmcmcAlgo::Sghmc, 0.0)).unwrap();
    for b in &batches {
        algo.step_all(&b.x, &b.y).unwrap();
    }
    let chained: Vec<Tensor> = algo.pd().drain_params().unwrap().into_values().collect();

    // reference: v ← (1−α) v − ε g;  θ ← θ + v
    let mut reference: Vec<Tensor> = (0..n).map(init_params).collect();
    let mut momenta: Vec<Tensor> = (0..n).map(|_| Tensor::zeros(vec![D])).collect();
    for b in &batches {
        for (p, v) in reference.iter_mut().zip(momenta.iter_mut()) {
            let g = native_grad(p, &b.x, &b.y);
            ops::scale_add(v, 1.0 - friction, -eps, &g);
            ops::axpy(p, 1.0, v);
        }
    }
    for (i, (c, r)) in chained.iter().zip(&reference).enumerate() {
        let diff = max_abs_diff(c, r);
        assert!(diff < 1e-6, "chain {i} diverged from momentum SGD: {diff}");
    }
}

#[test]
fn sghmc_momentum_roundtrips_through_checkpoint() {
    let n = 2;
    // temperature > 0: continuation only matches if the restored chain
    // clock re-aligns the per-step noise streams.
    let mk = || SgMcmc::new(pd(2, 2), chain_cfg(n, SgmcmcAlgo::Sghmc, 1e-3)).unwrap();
    let first = fixed_batches(6, 13);
    let second = fixed_batches(3, 14);

    let original = mk();
    for b in &first {
        original.step_all(&b.x, &b.y).unwrap();
    }
    let ck = Checkpoint::capture(original.pd()).unwrap();
    // captured state carries the chain: clock, momentum, reservoir
    for pid in original.pids() {
        let entries = &ck.state[&pid];
        let momentum = entries.iter().find(|(k, _)| k == push::infer::sgmcmc::K_MOM);
        assert!(momentum.is_some(), "{pid} momentum missing from checkpoint");
        let c = original.chain(pid);
        assert_eq!(c.step, first.len());
        assert!(c.momentum.is_some());
    }

    // file round-trip preserves everything, including the state section
    let dir = std::env::temp_dir().join(format!("push-sgmcmc-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("chain.ckpt");
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(ck, loaded);
    std::fs::remove_dir_all(&dir).ok();

    // restore into a fresh PD (fresh pids 0..n, fresh init) and continue:
    // both runs must produce identical parameters and momenta
    let restored = mk();
    loaded.restore(restored.pd()).unwrap();
    for b in &second {
        original.step_all(&b.x, &b.y).unwrap();
        restored.step_all(&b.x, &b.y).unwrap();
    }
    let a = original.pd().drain_params().unwrap();
    let b = restored.pd().drain_params().unwrap();
    for (pid, pa) in &a {
        let diff = max_abs_diff(pa, &b[pid]);
        assert!(diff < 1e-6, "{pid} diverged after restore: {diff}");
        let (ca, cb) = (original.chain(*pid), restored.chain(*pid));
        assert_eq!(ca.step, cb.step, "{pid} chain clock diverged");
        let (ma, mb) = (ca.momentum.unwrap(), cb.momentum.unwrap());
        assert!(max_abs_diff(&ma, &mb) < 1e-6, "{pid} momentum diverged");
        assert_eq!(ca.samples.len(), cb.samples.len());
    }
}

#[test]
fn reservoir_respects_burn_in_and_thinning_at_1024_particles() {
    let particles = 1024;
    let steps = 10;
    let (burn_in, thin, cap) = (3usize, 2usize, 2usize);
    let cfg = SgmcmcConfig {
        max_samples: cap,
        ..chain_cfg(particles, SgmcmcAlgo::Sgld, 1e-3)
    };
    assert_eq!(cfg.burn_in, burn_in);
    assert_eq!(cfg.thin, thin);
    let algo = SgMcmc::new(pd(2, 8), cfg).unwrap();
    let batches = fixed_batches(steps, 15);
    for b in &batches {
        algo.step_all(&b.x, &b.y).unwrap();
    }
    // candidates at t = 3, 5, 7, 9 → seen = 4, kept = min(cap, 4) = 2
    let want_seen = expected_candidates(steps, burn_in, thin);
    assert_eq!(want_seen, 4);
    let pids = algo.pids();
    assert_eq!(pids.len(), particles);
    for pid in pids {
        let c = algo.chain(pid);
        assert_eq!(c.step, steps, "{pid} chain clock");
        assert_eq!(c.seen, want_seen, "{pid} candidate count");
        assert_eq!(c.samples.len(), want_seen.min(cap), "{pid} reservoir size");
        for s in &c.samples {
            assert_eq!(s.element_count(), D);
            assert!(s.as_f32().iter().all(|v| v.is_finite()), "{pid} sample not finite");
        }
    }
}

#[test]
fn reservoir_stays_bounded_past_capacity() {
    // long chain, tiny reservoir: seen grows, kept stays at capacity
    let cfg = SgmcmcConfig {
        burn_in: 0,
        thin: 1,
        max_samples: 3,
        ..chain_cfg(2, SgmcmcAlgo::Sgld, 0.0)
    };
    let algo = SgMcmc::new(pd(1, 2), cfg).unwrap();
    let batches = fixed_batches(2, 16);
    let steps = 12;
    for i in 0..steps {
        let b = &batches[i % batches.len()];
        algo.step_all(&b.x, &b.y).unwrap();
    }
    for pid in algo.pids() {
        let c = algo.chain(pid);
        assert_eq!(c.seen, steps);
        assert_eq!(c.samples.len(), 3, "reservoir must stay at capacity");
    }
}

#[test]
fn posterior_predict_averages_reservoir_samples() {
    let algo = SgMcmc::new(
        pd(2, 2),
        SgmcmcConfig { burn_in: 2, thin: 1, ..chain_cfg(4, SgmcmcAlgo::Sgld, 1e-3) },
    )
    .unwrap();
    let batches = fixed_batches(4, 17);
    let b0 = batches[0].clone();

    // before any training: empty reservoir falls back to current params
    let cold = algo.predict_mean(&b0.x).unwrap();
    assert_eq!(cold.element_count(), b0.y.element_count());

    for _ in 0..3 {
        for b in &batches {
            algo.step_all(&b.x, &b.y).unwrap();
        }
    }
    for pid in algo.pids() {
        assert!(!algo.chain(pid).samples.is_empty(), "reservoir filled");
    }
    let pred = algo.predict_mean(&b0.x).unwrap();
    assert_eq!(pred.shape, b0.y.shape);
    assert!(pred.as_f32().iter().all(|v| v.is_finite()));
    // training toward the linear target must beat the cold prediction
    let before = push::infer::eval::batch_mse(&cold, &b0.y);
    let after = push::infer::eval::batch_mse(&pred, &b0.y);
    assert!(after < before, "posterior predictive did not improve: {before} -> {after}");
}
