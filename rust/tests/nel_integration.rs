//! Integration: NEL + PJRT runtime over real AOT artifacts (mlp_tiny).
//!
//! Requires `make artifacts` and a `--features pjrt` build; without the
//! feature this file compiles to an empty test binary so the default
//! `cargo test` stays hermetic. These tests exercise the full paper
//! machinery: particle creation (init artifact), message passing with
//! handlers, device compute (step/fwd/grad artifacts), parameter views,
//! cache pressure, and failure injection.
#![cfg(feature = "pjrt")]

use std::sync::Arc;

use push::device::CostModel;
use push::nel::CreateOpts;
use push::particle::{handler, PFuture, Value};
use push::runtime::{artifacts_dir, Manifest, Tensor};
use push::util::rng::Rng;
use push::{NelConfig, PushDist};

fn manifest() -> Manifest {
    Manifest::load(artifacts_dir()).expect("run `make artifacts` before cargo test")
}

fn cfg(devices: usize, cache: usize) -> NelConfig {
    NelConfig {
        num_devices: devices,
        cache_size: cache,
        cost: CostModel::free(),
        seed: 7,
        ..NelConfig::default()
    }
}

fn batch(md: &push::runtime::ModelSpec, seed: u64) -> (Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let xn: usize = md.x_shape.iter().product();
    let x = Tensor::f32(md.x_shape.clone(), rng.normal_vec(xn));
    let yn: usize = md.y_shape.iter().product();
    let y = Tensor::f32(md.y_shape.clone(), rng.normal_vec(yn));
    (x, y)
}

#[test]
fn particles_init_deterministically_per_pid() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(1, 4)).unwrap();
    let a = pd.p_create(CreateOpts::default()).unwrap();
    let b = pd.p_create(CreateOpts::default()).unwrap();
    let pa = pd.get(a).wait().unwrap().tensor().unwrap();
    let pb = pd.get(b).wait().unwrap().tensor().unwrap();
    assert_eq!(pa.element_count(), pd.model().param_count);
    assert_ne!(pa, pb, "different pids must get different init draws");

    // Same seed + same pid ordering in a fresh PD reproduces parameters.
    let pd2 = PushDist::new(&m, "mlp_tiny", cfg(1, 4)).unwrap();
    let a2 = pd2.p_create(CreateOpts::default()).unwrap();
    let pa2 = pd2.get(a2).wait().unwrap().tensor().unwrap();
    assert_eq!(pa, pa2);
}

#[test]
fn step_decreases_loss_and_matches_grad() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(1, 2)).unwrap();
    let p = pd.p_create(CreateOpts::default()).unwrap();
    let (x, y) = batch(pd.model(), 1);

    let before = pd.get(p).wait().unwrap().tensor().unwrap();
    let gl = pd.grad(p, x.clone(), y.clone()).wait().unwrap().list().unwrap();
    let loss_g = gl[0].as_tensor().unwrap().scalar();
    let grad = gl[1].as_tensor().unwrap().clone();

    let loss_s = pd
        .step(p, x.clone(), y.clone(), 0.01)
        .wait()
        .unwrap()
        .tensor()
        .unwrap()
        .scalar();
    assert!((loss_g - loss_s).abs() < 1e-5, "{loss_g} vs {loss_s}");

    // step == params - lr * grad
    let after = pd.get(p).wait().unwrap().tensor().unwrap();
    for i in 0..after.element_count() {
        let want = before.as_f32()[i] - 0.01 * grad.as_f32()[i];
        assert!((after.as_f32()[i] - want).abs() < 1e-5);
    }

    // and a couple hundred steps actually learn
    let mut last = f32::MAX;
    for _ in 0..200 {
        last = pd
            .step(p, x.clone(), y.clone(), 0.02)
            .wait()
            .unwrap()
            .tensor()
            .unwrap()
            .scalar();
    }
    assert!(last < 0.5 * loss_s, "loss {loss_s} -> {last}");
}

#[test]
fn all_to_all_gather_via_handlers() {
    // The paper's Figure 1 `_gather` pattern, verbatim in Rust.
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(2, 4)).unwrap();
    let gather = handler(|ctx, _args| {
        let others = ctx.other_particles();
        let futs: Vec<PFuture> = others.iter().map(|p| ctx.get(*p)).collect();
        let views = PFuture::wait_all(&futs)?;
        let mut total = 0usize;
        for v in &views {
            total += v.as_tensor()?.element_count();
        }
        Ok(Value::Usize(total))
    });
    let mk = |_i: usize| CreateOpts {
        receive: [("GATHER".to_string(), gather.clone())].into_iter().collect(),
        ..CreateOpts::default()
    };
    let pids = pd.p_create_n(4, mk).unwrap();
    let fut = pd.p_launch(pids[0], "GATHER", vec![]);
    let total = fut.wait().unwrap().usize().unwrap();
    assert_eq!(total, 3 * pd.model().param_count);
    let stats = pd.stats();
    assert!(stats.msgs_sent >= 1);
}

#[test]
fn cache_pressure_swaps_and_preserves_params() {
    let m = manifest();
    // 6 particles on 1 device with 2 active-set slots: heavy swapping.
    let pd = PushDist::new(&m, "mlp_tiny", cfg(1, 2)).unwrap();
    let pids = pd.p_create_n(6, |_| CreateOpts::default()).unwrap();
    let (x, y) = batch(pd.model(), 3);
    let snapshot: Vec<Tensor> = pids
        .iter()
        .map(|p| pd.get(*p).wait().unwrap().tensor().unwrap())
        .collect();
    // interleave steps across all particles twice
    for _ in 0..2 {
        let futs: Vec<PFuture> = pids
            .iter()
            .map(|p| pd.step(*p, x.clone(), y.clone(), 0.01))
            .collect();
        PFuture::wait_all(&futs).unwrap();
    }
    let stats = pd.stats();
    let dev = &stats.devices[0];
    assert!(dev.swaps_out > 0, "must have evicted under pressure");
    // params all updated & distinct from their snapshots
    for (p, before) in pids.iter().zip(&snapshot) {
        let after = pd.get(*p).wait().unwrap().tensor().unwrap();
        assert_ne!(&after, before);
    }
}

#[test]
fn drain_params_returns_everything() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(2, 2)).unwrap();
    let pids = pd.p_create_n(5, |_| CreateOpts::default()).unwrap();
    let snap = pd.drain_params().unwrap();
    assert_eq!(snap.len(), 5);
    for p in pids {
        assert_eq!(snap[&p].element_count(), pd.model().param_count);
    }
}

#[test]
fn unknown_message_and_handler_panic_surface_as_errors() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(1, 2)).unwrap();
    let boom = handler(|_ctx, _args| panic!("injected failure"));
    let p = pd
        .p_create(CreateOpts {
            receive: [("BOOM".to_string(), boom)].into_iter().collect(),
            ..CreateOpts::default()
        })
        .unwrap();

    let err = pd.p_launch(p, "NOPE", vec![]).wait().unwrap_err();
    assert!(err.msg.contains("no handler"), "{err}");

    let err = pd.p_launch(p, "BOOM", vec![]).wait().unwrap_err();
    assert!(err.msg.contains("injected failure"), "{err}");
    assert_eq!(pd.stats().handler_errors, 2);

    // the particle survives failures and keeps processing messages
    let ok = pd.get(p).wait();
    assert!(ok.is_ok());
}

#[test]
fn mean_forward_averages_particles() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(2, 4)).unwrap();
    let pids = pd.p_create_n(3, |_| CreateOpts::default()).unwrap();
    let (x, _) = batch(pd.model(), 5);
    let mean = pd.mean_forward(&pids, &x).unwrap();
    // manual average
    let preds: Vec<Tensor> = pids
        .iter()
        .map(|p| pd.forward(*p, x.clone()).wait().unwrap().tensor().unwrap())
        .collect();
    for i in 0..mean.element_count() {
        let want = preds.iter().map(|t| t.as_f32()[i]).sum::<f32>() / 3.0;
        assert!((mean.as_f32()[i] - want).abs() < 1e-5);
    }
}

#[test]
fn svgd_artifact_runs_and_matches_contract() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_small", cfg(1, 4)).unwrap();
    let d = pd.model().param_count;
    let path = pd.svgd_artifact(2).expect("svgd artifact for mlp_small n=2");
    let mut rng = Rng::new(9);
    let p = Tensor::f32(vec![2, d], rng.normal_vec(2 * d));
    let g = Tensor::f32(vec![2, d], rng.normal_vec(2 * d));
    let h = Tensor::scalar_f32(1.0);
    let out = pd
        .nel()
        .run_artifact(0, path, vec![p.clone(), g.clone(), h])
        .wait()
        .unwrap()
        .tensor()
        .unwrap();
    assert_eq!(out.shape, vec![2, d]);
    // far-apart particles (random init in high-d): K ~ I, U ~ g / n
    for i in 0..out.element_count() {
        let want = g.as_f32()[i] / 2.0;
        assert!(
            (out.as_f32()[i] - want).abs() < 2e-2 + 0.05 * want.abs(),
            "U[{i}] = {} vs g/n = {want}",
            out.as_f32()[i]
        );
    }
}

#[test]
fn trace_records_figure3b_events() {
    let m = manifest();
    let mut c = cfg(1, 2);
    c.trace = true;
    let pd = PushDist::new(&m, "mlp_tiny", c).unwrap();
    let noop = handler(|_ctx, _| Ok(Value::Unit));
    let p = pd
        .p_create(CreateOpts {
            receive: [("PING".to_string(), noop)].into_iter().collect(),
            ..CreateOpts::default()
        })
        .unwrap();
    pd.p_launch(p, "PING", vec![]).wait().unwrap();
    pd.get(p).wait().unwrap();
    let text = pd.nel().trace().to_text();
    for needle in ["create", "msg_send", "handler_start", "handler_end", "job_start", "swap_in"] {
        assert!(text.contains(needle), "trace missing {needle}:\n{text}");
    }
}

#[test]
fn cross_device_view_charges_transfer() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(2, 4)).unwrap();
    // particle 0 -> device 0, particle 1 -> device 1 (round robin)
    let pids = pd.p_create_n(2, |_| CreateOpts::default()).unwrap();
    let view = handler(|ctx, args| {
        let target = push::Pid(args[0].usize()? as u32);
        ctx.get(target).wait()
    });
    let pd2 = pd; // readability
    let p = pd2
        .p_create(CreateOpts {
            device: Some(0),
            receive: [("VIEW".to_string(), view)].into_iter().collect(),
            ..CreateOpts::default()
        })
        .unwrap();
    // view particle 1 (device 1) from particle p (device 0): cross-device
    pd2.p_launch(p, "VIEW", vec![Value::Usize(pids[1].0 as usize)])
        .wait()
        .unwrap();
    let stats = pd2.stats();
    let d1 = &stats.devices[1];
    assert!(d1.transfers >= 1, "expected a cross-device transfer: {d1:?}");
    assert!(d1.transfer_bytes as usize >= pd2.model().param_count * 4);
}
