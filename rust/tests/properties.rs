//! Property-based tests over randomized inputs (in-repo generator — the
//! vendored crate set has no proptest). Each property runs against many
//! seeded cases; failures print the seed for reproduction.
//!
//! Covered invariants:
//! * JSON printer/parser round-trip on random documents
//! * Flags parser never panics and preserves positional order
//! * ResidentCache: slot/byte budgets, single-authority, no data loss
//! * svgd_update_native: permutation equivariance, large-h limit
//! * SWAG streaming moments match batch recomputation
//! * DataLoader epochs cover each sample at most once
//! * PrefetchLoader batch streams are byte-identical to the synchronous
//!   DataLoader epochs across a (seed, batch_size, max_batches, shuffle)
//!   grid — asynchrony changes timing, never data (DESIGN.md §10)
//! * Wire codec: arbitrary nested Value round-trip, truncated/oversized
//!   frame rejection, pid decode rejecting values beyond the u32 pid
//!   space (no silent wraparound), and checkpoint-file/wire-codec byte
//!   identity (the v1/v2 checkpoint compatibility seam)
//! * Elastic-fabric messages: Heartbeat/Migrate round-trip with arbitrary
//!   nested chain state, strict-prefix truncation of any encoded request
//!   fails to decode, and unknown kind bytes error cleanly (a v-next peer
//!   can't wedge a v1 node)
//! * Kernel plane: every kernel is bit-identical across the scalar
//!   reference tier, every detected SIMD backend, and any worker-pool
//!   thread count, over a shape grid covering ragged lane remainders,
//!   the sharding threshold, len 0/1, and NaN/inf inputs; plus one full
//!   native-MLP gradient + drift step, kernels off vs on (DESIGN.md §14)

use std::collections::BTreeMap;

use push::device::{CostModel, HostStore, ResidentCache};
use push::device::stats::DeviceStats;
use push::infer::svgd_update_native;
use push::nel::trace::Trace;
use push::runtime::tensor::ops;
use push::runtime::Tensor;
use push::util::json::Json;
use push::util::rng::Rng;
use push::Pid;

const CASES: u64 = 60;

// ---------------------------------------------------------------- json
fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => {
            // pretty() prints integers exactly; fractional values go
            // through f64 formatting which round-trips via parse.
            let v = (rng.normal() * 1e6) as i64 as f64;
            Json::Num(if rng.below(2) == 0 { v } else { v / 64.0 })
        }
        3 => {
            let n = rng.below(8);
            let s: String = (0..n)
                .map(|_| {
                    let c = rng.below(96) as u8 + 32;
                    c as char
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let mut m = BTreeMap::new();
            for i in 0..rng.below(4) {
                m.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let doc = random_json(&mut rng, 3);
        let text = doc.pretty();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(doc, back, "seed {seed}");
    }
}

// --------------------------------------------------------------- flags
#[test]
fn prop_flags_never_panic_and_keep_positional_order() {
    let vocab = ["--a", "--b=1", "x", "y", "--", "--c", "7", "-z", "--d=--e"];
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xf1a6);
        let n = rng.below(10);
        let args: Vec<String> =
            (0..n).map(|_| vocab[rng.below(vocab.len())].to_string()).collect();
        let f = push::util::flags::Flags::parse(args.clone()).unwrap();
        // positional tokens (ignoring flags and values they consume)
        // must appear in f.positional in their original relative order
        let mut pos_iter = f.positional.iter();
        let mut last_found: Option<&String> = None;
        for p in &f.positional {
            assert!(pos_iter.any(|q| q == p), "seed {seed}: {args:?}");
            last_found = Some(p);
        }
        let _ = last_found;
    }
}

// --------------------------------------------------------------- cache
#[test]
fn prop_cache_budgets_and_no_data_loss() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xcac4e);
        let capacity = 1 + rng.below(4);
        let n_particles = 1 + rng.below(10);
        let elems = 1 + rng.below(16);
        let budget = (capacity * elems * 4).max(elems * 4);
        let mut cache = ResidentCache::new(capacity, budget, CostModel::free());
        let host = HostStore::default();
        let trace = Trace::disabled();
        let mut stats = DeviceStats::default();

        // every particle's canonical value: pid-tagged, mutated over time
        let mut expected: Vec<f32> = (0..n_particles).map(|i| i as f32).collect();
        for i in 0..n_particles {
            host.insert(Pid(i as u32), Tensor::f32(vec![elems], vec![expected[i]; elems]));
        }

        for _op in 0..200 {
            let i = rng.below(n_particles);
            let pid = Pid(i as u32);
            match rng.below(3) {
                0 => {
                    let t = cache
                        .ensure_resident(pid, &host, &mut stats, &trace, 0)
                        .unwrap();
                    assert_eq!(t.as_f32()[0], expected[i], "seed {seed}: stale read");
                }
                1 => {
                    expected[i] += 1.0;
                    let t = cache
                        .ensure_resident(pid, &host, &mut stats, &trace, 0)
                        .unwrap();
                    for v in t.as_f32_mut() {
                        *v = expected[i];
                    }
                }
                _ => {
                    cache.flush(pid, &host);
                }
            }
            // invariants
            assert!(cache.resident_count() <= capacity, "seed {seed}: slots");
            assert!(cache.resident_bytes() <= budget, "seed {seed}: bytes");
            // single authority: each particle resident XOR in host store
            for j in 0..n_particles {
                let p = Pid(j as u32);
                assert!(
                    cache.is_resident(p) ^ host.contains(p),
                    "seed {seed}: dual authority for {p}"
                );
            }
        }
        // drain and verify nothing was lost
        cache.flush_all(&host);
        for j in 0..n_particles {
            let t = host.get_clone(Pid(j as u32)).unwrap();
            assert_eq!(t.as_f32()[0], expected[j], "seed {seed}: lost write");
        }
    }
}

// ---------------------------------------------------------------- svgd
#[test]
fn prop_svgd_permutation_equivariance() {
    for seed in 0..CASES / 2 {
        let mut rng = Rng::new(seed ^ 0x57d);
        let n = 2 + rng.below(5);
        let d = 1 + rng.below(32);
        let p: Vec<Tensor> = (0..n).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
        let g: Vec<Tensor> = (0..n).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
        let h = rng.uniform_in(0.5, 4.0);
        let u = svgd_update_native(&p, &g, h).unwrap();

        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let pp: Vec<Tensor> = perm.iter().map(|&i| p[i].clone()).collect();
        let gp: Vec<Tensor> = perm.iter().map(|&i| g[i].clone()).collect();
        let up = svgd_update_native(&pp, &gp, h).unwrap();
        for (k, &i) in perm.iter().enumerate() {
            for (a, b) in up[k].as_f32().iter().zip(u[i].as_f32()) {
                assert!((a - b).abs() < 1e-4, "seed {seed}: not equivariant");
            }
        }
    }
}

#[test]
fn prop_svgd_large_h_limit_is_mean_gradient() {
    // h -> inf: k_ij -> 1 and the repulsion vanishes, so U_i -> mean_j g_j.
    for seed in 0..CASES / 2 {
        let mut rng = Rng::new(seed ^ 0x1a26e);
        let n = 2 + rng.below(4);
        let d = 1 + rng.below(16);
        let p: Vec<Tensor> = (0..n).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
        let g: Vec<Tensor> = (0..n).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
        let u = svgd_update_native(&p, &g, 1e6).unwrap();
        for i in 0..n {
            for t in 0..d {
                let mean_g: f32 = g.iter().map(|gj| gj.as_f32()[t]).sum::<f32>() / n as f32;
                assert!(
                    (u[i].as_f32()[t] - mean_g).abs() < 1e-3,
                    "seed {seed}: U[{i}][{t}] != mean gradient"
                );
            }
        }
    }
}

// ---------------------------------------------------------------- swag
#[test]
fn prop_streaming_moments_match_batch() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x5a46);
        let d = 1 + rng.below(24);
        let steps = 1 + rng.below(30);
        let mut mean = Tensor::zeros(vec![d]);
        let mut sq = Tensor::zeros(vec![d]);
        let mut history: Vec<Vec<f32>> = Vec::new();
        for n in 0..steps {
            let x = Tensor::f32(vec![d], rng.normal_vec(d));
            let w_old = n as f32 / (n as f32 + 1.0);
            let w_new = 1.0 / (n as f32 + 1.0);
            ops::scale_add(&mut mean, w_old, w_new, &x);
            ops::scale_add_sq(&mut sq, w_old, w_new, &x);
            history.push(x.as_f32().to_vec());
        }
        for t in 0..d {
            let batch_mean: f32 =
                history.iter().map(|h| h[t]).sum::<f32>() / steps as f32;
            let batch_sq: f32 =
                history.iter().map(|h| h[t] * h[t]).sum::<f32>() / steps as f32;
            assert!((mean.as_f32()[t] - batch_mean).abs() < 1e-4, "seed {seed}");
            assert!((sq.as_f32()[t] - batch_sq).abs() < 1e-4, "seed {seed}");
        }
    }
}

// -------------------------------------------------------------- loader
#[test]
fn prop_loader_no_repeats_within_epoch() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x10ade5);
        let n = 4 + rng.below(60);
        let bsz = 1 + rng.below(n.min(12));
        let mut d = push::data::Dataset::new_f32(vec![1], vec![1]);
        for i in 0..n {
            d.push_f32(&[i as f32], &[0.0]);
        }
        let mut loader = push::data::DataLoader::new(d, bsz, true, seed);
        for _epoch in 0..3 {
            let batches = loader.epoch();
            assert_eq!(batches.len(), n / bsz, "seed {seed}");
            let mut seen: Vec<i64> = batches
                .iter()
                .flat_map(|b| b.x.as_f32().iter().map(|v| *v as i64).collect::<Vec<_>>())
                .collect();
            let len_before = seen.len();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), len_before, "seed {seed}: repeated sample");
        }
    }
}

/// Bit-level equality of two batches (stricter than Tensor's PartialEq:
/// f32 payloads are compared by bit pattern, i32 labels exactly).
fn batch_bits_equal(a: &push::data::Batch, b: &push::data::Batch) -> bool {
    use push::runtime::DType;
    if a.x.shape != b.x.shape || a.y.shape != b.y.shape {
        return false;
    }
    let x_same = a
        .x
        .as_f32()
        .iter()
        .zip(b.x.as_f32())
        .all(|(p, q)| p.to_bits() == q.to_bits());
    let y_same = match a.y.dtype() {
        DType::I32 => a.y.as_i32() == b.y.as_i32(),
        _ => a
            .y
            .as_f32()
            .iter()
            .zip(b.y.as_f32())
            .all(|(p, q)| p.to_bits() == q.to_bits()),
    };
    x_same && y_same
}

#[test]
fn prop_prefetch_stream_equals_sync() {
    use push::data::{BatchSource, DataLoader, Dataset, PrefetchLoader};

    // (n, batch_size, max_batches, classify): covers the ragged-tail-drop
    // edge (n % batch_size != 0), the single-full-batch edge
    // (n == batch_size), a max_batches cap tighter than the data, a cap
    // looser than the data, and an i32-label dataset.
    let cases: &[(usize, usize, Option<usize>, bool)] = &[
        (13, 4, None, false),       // ragged tail: 13 % 4 != 0
        (8, 8, None, false),        // n == batch_size: exactly one batch
        (24, 4, Some(3), false),    // cap below the 6 available batches
        (20, 5, Some(99), false),   // cap above the 4 available batches
        (10, 3, None, true),        // classify labels + ragged tail
        (9, 2, Some(2), true),      // classify + cap + ragged tail
    ];
    let mk_data = |n: usize, classify: bool| -> Dataset {
        if classify {
            let mut d = Dataset::new_classify(vec![3]);
            for i in 0..n {
                let f = i as f32;
                d.push_classify(&[f, -f, 0.5 * f], (i % 4) as i32);
            }
            d
        } else {
            let mut d = Dataset::new_f32(vec![2], vec![1]);
            for i in 0..n {
                let f = i as f32;
                d.push_f32(&[f, -f], &[2.0 * f]);
            }
            d
        }
    };

    for seed in 0..6u64 {
        for &shuffle in &[false, true] {
            for (ci, &(n, bsz, cap, classify)) in cases.iter().enumerate() {
                let mk_loader = || {
                    let mut l = DataLoader::new(mk_data(n, classify), bsz, shuffle, seed);
                    if let Some(m) = cap {
                        l = l.with_max_batches(m);
                    }
                    l
                };
                let mut sync = mk_loader();
                let mut pre = PrefetchLoader::new(mk_loader());
                assert_eq!(pre.batches_per_epoch(), sync.batches_per_epoch());
                // 3 epochs: the shuffle stream must advance identically
                // epoch over epoch on both paths
                for epoch in 0..3 {
                    let want = sync.epoch();
                    let stream = pre.epoch_stream();
                    assert_eq!(
                        stream.len(),
                        want.len(),
                        "seed {seed} case {ci} epoch {epoch}: stream length"
                    );
                    let mut got = Vec::new();
                    for b in stream {
                        got.push(b);
                    }
                    assert_eq!(
                        got.len(),
                        want.len(),
                        "seed {seed} case {ci} epoch {epoch}: batch count"
                    );
                    for (bi, (w, g)) in want.iter().zip(&got).enumerate() {
                        assert!(
                            batch_bits_equal(w, g),
                            "seed {seed} case {ci} (n={n} bsz={bsz} cap={cap:?} \
                             classify={classify} shuffle={shuffle}) epoch {epoch} \
                             batch {bi}: prefetch diverged from sync"
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------- wire
#[test]
fn prop_wire_value_roundtrip_arbitrary_nested() {
    use push::pd::wire;
    for seed in 0..CASES * 2 {
        let mut rng = Rng::new(seed ^ 0x3173c0de);
        let v = wire::arbitrary_value(&mut rng, 3);
        let mut buf = Vec::new();
        wire::write_value(&mut buf, &v, 0).unwrap();
        let mut r = buf.as_slice();
        let back = wire::read_value(&mut r, 0).unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        assert!(r.is_empty(), "seed {seed}: {} trailing bytes", r.len());
        assert_eq!(back, v, "seed {seed}");
    }
}

#[test]
fn prop_wire_truncated_and_oversized_frames_rejected() {
    use push::pd::wire;
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x7c47);
        let v = wire::arbitrary_value(&mut rng, 2);
        let mut payload = Vec::new();
        wire::write_value(&mut payload, &v, 0).unwrap();
        let mut framed = Vec::new();
        wire::write_frame(&mut framed, &payload).unwrap();
        // whole frame decodes
        let back = wire::read_frame(&mut framed.as_slice()).unwrap();
        assert_eq!(back, payload, "seed {seed}");
        // any strict prefix of the frame must fail to decode as a frame
        let cut = rng.below(framed.len().max(1));
        if cut < framed.len() {
            assert!(
                wire::read_frame(&mut &framed[..cut]).is_err(),
                "seed {seed}: truncation to {cut}/{} accepted",
                framed.len()
            );
        }
    }
    // a frame header claiming more than MAX_FRAME errors without allocating
    let huge = (u32::MAX).to_le_bytes();
    assert!(wire::read_frame(&mut &huge[..]).is_err());
}

#[test]
fn prop_wire_pid_decode_rejects_beyond_u32_instead_of_wrapping() {
    use push::pd::transport::decode_wire_pid;
    // the whole u32 pid space round-trips, boundary included
    for seed in 0..CASES {
        let pid = Rng::new(seed ^ 0x91d).below(u32::MAX as usize) as u32;
        assert_eq!(decode_wire_pid(pid as usize).unwrap(), Pid(pid), "seed {seed}");
    }
    assert_eq!(decode_wire_pid(u32::MAX as usize).unwrap(), Pid(u32::MAX));
    // one past the boundary must be a decode error NAMING the raw value —
    // the old `as u32` cast silently wrapped pid 2^32 to pid 0, aliasing
    // a remote particle onto a local one
    #[cfg(target_pointer_width = "64")]
    {
        let raw = (u32::MAX as usize) + 1;
        let err = decode_wire_pid(raw).unwrap_err();
        assert!(err.msg.contains(&raw.to_string()), "raw value not named: {err}");
        assert!(err.msg.contains("truncation"), "{err}");
        assert!(decode_wire_pid(usize::MAX).is_err());
    }
}

#[test]
fn prop_wire_heartbeat_and_migrate_roundtrip() {
    use push::pd::wire::{self, CreateSpec, Request};
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xe1a57);

        // heartbeats: fixed-size, tensor-free, nonce echoed exactly
        let nonce = rng.below(1 << 30) as u64 ^ (seed << 32);
        let hb = Request::Heartbeat { nonce };
        let buf = wire::encode_request(seed, &hb).unwrap();
        assert!(
            buf.len() < 64,
            "seed {seed}: a heartbeat encoded to {} bytes (must never carry payload)",
            buf.len()
        );
        let (id, back) = wire::decode_request(&buf).unwrap();
        assert_eq!(id, seed, "seed {seed}");
        assert_eq!(back, hb, "seed {seed}");

        // migrate batches: every spec field crosses intact, arbitrary
        // nested chain state included (reservoirs are lists of tensors)
        let n = 1 + rng.below(4);
        let specs: Vec<CreateSpec> = (0..n)
            .map(|i| {
                let d = 1 + rng.below(8);
                CreateSpec {
                    pid: Pid(rng.below(1 << 16) as u32),
                    device: if rng.below(2) == 0 { None } else { Some(rng.below(4)) },
                    program: Some((
                        "sgmcmc".to_string(),
                        wire::arbitrary_value(&mut rng, 2),
                    )),
                    state: (0..rng.below(3))
                        .map(|k| (format!("k{k}"), wire::arbitrary_value(&mut rng, 2)))
                        .collect(),
                    no_params: rng.below(2) == 0,
                    init_params: if i % 2 == 0 {
                        Some(Tensor::f32(vec![d], rng.normal_vec(d)))
                    } else {
                        None
                    },
                    model: "linear_native".to_string(),
                }
            })
            .collect();
        let mig = Request::Migrate { specs };
        let buf = wire::encode_request(seed + 1, &mig).unwrap();
        let (id, back) = wire::decode_request(&buf).unwrap();
        assert_eq!(id, seed + 1, "seed {seed}");
        assert_eq!(back, mig, "seed {seed}");
    }
}

#[test]
fn prop_wire_request_strict_prefix_fails_to_decode() {
    use push::pd::wire::{self, CreateSpec, Request};
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x7afc);
        // cycle through every request shape, including zero-body ones
        // (where the prefix must die on the header reads)
        let req = match rng.below(7) {
            0 => Request::Heartbeat { nonce: seed },
            6 => Request::SnapshotNode {
                pids: (0..1 + rng.below(5)).map(|_| Pid(rng.below(1 << 10) as u32)).collect(),
            },
            1 => Request::Migrate {
                specs: vec![CreateSpec {
                    pid: Pid(7),
                    device: None,
                    program: None,
                    state: vec![("s".to_string(), wire::arbitrary_value(&mut rng, 2))],
                    no_params: false,
                    init_params: Some(Tensor::f32(vec![3], rng.normal_vec(3))),
                    model: "m".to_string(),
                }],
            },
            2 => Request::Send {
                pid: Pid(rng.below(99) as u32),
                msg: "MCMC_STEP".to_string(),
                args: vec![wire::arbitrary_value(&mut rng, 2)],
            },
            3 => Request::Stats,
            4 => Request::ParticleState { pid: Pid(3) },
            _ => Request::RestoreState {
                pid: Pid(1),
                entries: vec![("k".to_string(), wire::arbitrary_value(&mut rng, 1))],
            },
        };
        let buf = wire::encode_request(seed, &req).unwrap();
        assert_eq!(wire::decode_request(&buf).unwrap().1, req, "seed {seed}");
        // EVERY strict prefix must fail: each field is read eagerly, so a
        // cut anywhere leaves a read wanting bytes — no prefix may alias
        // to a shorter valid request
        for cut in 0..buf.len() {
            assert!(
                wire::decode_request(&buf[..cut]).is_err(),
                "seed {seed}: prefix {cut}/{} decoded as a request",
                buf.len()
            );
        }
    }
}

#[test]
fn prop_wire_unknown_request_kind_errors_cleanly() {
    use push::pd::wire::{self, Request};
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xbadc0de);
        // a valid header whose kind byte is from the future:
        // K_SNAPSHOT_NODE=12 is the newest kind, so 13..=255 must all be
        // rejected by name
        let mut buf = wire::encode_request(seed, &Request::Heartbeat { nonce: 9 }).unwrap();
        let bogus = 13 + rng.below(243) as u8;
        buf[1] = bogus;
        let err = wire::decode_request(&buf).unwrap_err();
        assert!(
            format!("{err:#}").contains("unknown request kind"),
            "seed {seed}: kind {bogus}: {err:#}"
        );
    }
}

#[test]
fn prop_wire_snapshot_node_roundtrip_and_fanout_bound() {
    use push::pd::wire::{self, Request};
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x54a9);
        // arbitrary pid sets round-trip exactly, in order, empty included
        // (an empty batch is legal on the wire; the fabric just never
        // sends one)
        let n = rng.below(32);
        let pids: Vec<Pid> = (0..n).map(|_| Pid(rng.below(1 << 20) as u32)).collect();
        let req = Request::SnapshotNode { pids };
        let buf = wire::encode_request(seed, &req).unwrap();
        let (id, back) = wire::decode_request(&buf).unwrap();
        assert_eq!(id, seed, "seed {seed}");
        assert_eq!(back, req, "seed {seed}");
        // a batch is one small frame: header + 4 bytes + 4 bytes per pid
        assert_eq!(buf.len(), 1 + 1 + 8 + 4 + 4 * n, "seed {seed}: encoding grew");

        // a tampered count claiming an implausible fan-out is rejected
        // BEFORE any allocation, by name
        let mut evil = buf.clone();
        let count_at = 1 + 1 + 8;
        evil[count_at..count_at + 4].copy_from_slice(&(1u32 << 30).to_le_bytes());
        let err = wire::decode_request(&evil).unwrap_err();
        assert!(
            format!("{err:#}").contains("implausible snapshot fan-out"),
            "seed {seed}: {err:#}"
        );
    }
}

#[test]
fn checkpoint_state_section_uses_the_shared_wire_codec_bytes() {
    use push::pd::checkpoint::Checkpoint;
    use push::pd::wire;
    use push::particle::Value;

    // one particle, one state entry with a distinctive nested value
    let value = Value::List(vec![
        Value::Usize(0xA5A5),
        Value::Tensor(Tensor::f32(vec![3], vec![1.5, -2.5, 3.25])),
        Value::Str("codec-seam".to_string()),
    ]);
    let mut params = BTreeMap::new();
    params.insert(Pid(0), Tensor::f32(vec![2], vec![0.5, 1.0]));
    let mut state = BTreeMap::new();
    state.insert(Pid(0), vec![("k".to_string(), value.clone())]);
    let ck = Checkpoint { model: "m".into(), params, state };

    let dir = std::env::temp_dir().join(format!("push-prop-wire-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("seam.ckpt");
    ck.save(&path).unwrap();
    let file_bytes = std::fs::read(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();

    // the v2 state section must embed EXACTLY the wire codec's bytes for
    // the value — checkpoint files and transport frames speak one dialect
    let mut wire_bytes = Vec::new();
    wire::write_value(&mut wire_bytes, &value, 0).unwrap();
    let found = file_bytes
        .windows(wire_bytes.len())
        .any(|w| w == wire_bytes.as_slice());
    assert!(found, "checkpoint file does not contain the wire-codec encoding");

    // and the file still round-trips through the checkpoint loader
    let dir = std::env::temp_dir().join(format!("push-prop-wire2-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("seam2.ckpt");
    std::fs::write(&path, &file_bytes).unwrap();
    assert_eq!(Checkpoint::load(&path).unwrap(), ck);
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------- native model gradcheck

fn native_loss(
    src: &push::infer::ModelSource,
    params: &Tensor,
    x: &Tensor,
    y: &Tensor,
) -> f32 {
    let push::infer::ModelSource::Native { grad, .. } = src else { panic!("native source") };
    grad(params, x, y).expect("native loss").0
}

fn native_grad(
    src: &push::infer::ModelSource,
    params: &Tensor,
    x: &Tensor,
    y: &Tensor,
) -> Tensor {
    let push::infer::ModelSource::Native { grad, .. } = src else { panic!("native source") };
    grad(params, x, y).expect("native grad").1
}

/// Central finite difference vs the closed-form gradient at every (or a
/// random subset of) parameter coordinates. The caller guarantees the
/// probe step cannot cross a ReLU kink (margin search below).
fn gradcheck_native(
    label: &str,
    src: &push::infer::ModelSource,
    params: &Tensor,
    x: &Tensor,
    y: &Tensor,
    rng: &mut Rng,
) {
    let h = 1e-3f32;
    let g = native_grad(src, params, x, y);
    let gs = g.as_f32().to_vec();
    let n = gs.len();
    let probes: Vec<usize> = if n <= 24 {
        (0..n).collect()
    } else {
        (0..24).map(|_| rng.below(n)).collect()
    };
    for j in probes {
        let mut plus = params.clone();
        plus.as_f32_mut()[j] += h;
        let mut minus = params.clone();
        minus.as_f32_mut()[j] -= h;
        let fd = (native_loss(src, &plus, x, y) - native_loss(src, &minus, x, y)) / (2.0 * h);
        let tol = 5e-3 + 0.05 * gs[j].abs();
        assert!(
            (fd - gs[j]).abs() <= tol,
            "{label}: param {j}: analytic {} vs central-difference {fd}",
            gs[j]
        );
    }
}

#[test]
fn prop_native_mlp_gradcheck_matches_finite_difference() {
    use push::infer::{models, Activation, MlpSpec};
    let b = 4usize;
    for depth in 1..=3usize {
        for act in [Activation::Relu, Activation::Tanh] {
            for classify in [false, true] {
                let spec =
                    MlpSpec { in_dim: 3, hidden: 4, depth, out_dim: 2, activation: act };
                let src = models::mlp_model(spec);
                let salt = depth as u64 * 16
                    + u64::from(act == Activation::Tanh) * 4
                    + u64::from(classify) * 2;
                // ReLU: redraw until every hidden pre-activation clears the
                // kink by far more than the probe step can move it; tanh is
                // smooth and accepts the first draw.
                let mut found = None;
                for case in 0..200u64 {
                    let mut r = Rng::new(0x6d6c_7031).fold_in(salt).fold_in(case);
                    let pv: Vec<f32> =
                        r.normal_vec(spec.param_count()).iter().map(|v| 0.5 * v).collect();
                    let params = Tensor::f32(vec![spec.param_count()], pv);
                    let x = Tensor::f32(vec![b, 3], r.normal_vec(b * 3));
                    let margin = spec.min_abs_preactivation(&params, &x).unwrap();
                    if act == Activation::Tanh || margin > 0.05 {
                        found = Some((params, x, r));
                        break;
                    }
                }
                let (params, x, mut r) = found.expect("a kink-free draw exists in 200 cases");
                let y = if classify {
                    Tensor::i32(vec![b], (0..b).map(|_| r.below(2) as i32).collect())
                } else {
                    Tensor::f32(vec![b, 2], r.normal_vec(b * 2))
                };
                let label = format!(
                    "mlp depth={depth} {} {}",
                    act.name(),
                    if classify { "ce" } else { "mse" }
                );
                gradcheck_native(&label, &src, &params, &x, &y, &mut r);
            }
        }
    }
}

#[test]
fn prop_native_conv1d_gradcheck_matches_finite_difference() {
    use push::infer::{models, Activation, Conv1dSpec};
    let b = 3usize;
    let mut shape_rng = Rng::new(0x636f_6e76);
    for act in [Activation::Relu, Activation::Tanh] {
        for classify in [false, true] {
            for case in 0..3u64 {
                let nx = 8 + shape_rng.below(8);
                let kernel = 2 + shape_rng.below(4);
                let channels = 1 + shape_rng.below(3);
                let out_dim = if classify { 2 } else { 1 + shape_rng.below(2) };
                let spec = Conv1dSpec { nx, channels, kernel, out_dim, activation: act };
                let src = models::conv1d_model(spec);
                let salt = u64::from(act == Activation::Tanh) * 8
                    + u64::from(classify) * 4
                    + case;
                // conv maps have many units, so accept a smaller (still
                // safely > h * max|x|) kink margin than the MLP check
                let mut found = None;
                for draw in 0..400u64 {
                    let mut r = Rng::new(0x6376_3164).fold_in(salt).fold_in(draw);
                    let pv: Vec<f32> =
                        r.normal_vec(spec.param_count()).iter().map(|v| 0.5 * v).collect();
                    let params = Tensor::f32(vec![spec.param_count()], pv);
                    let x = Tensor::f32(vec![b, nx], r.normal_vec(b * nx));
                    let margin = spec.min_abs_preactivation(&params, &x).unwrap();
                    if act == Activation::Tanh || margin > 0.02 {
                        found = Some((params, x, r));
                        break;
                    }
                }
                let (params, x, mut r) = found.expect("a kink-free draw exists in 400 cases");
                let y = if classify {
                    Tensor::i32(vec![b], (0..b).map(|_| r.below(out_dim) as i32).collect())
                } else {
                    Tensor::f32(vec![b, out_dim], r.normal_vec(b * out_dim))
                };
                let label = format!(
                    "conv1d nx={nx} k={kernel} c={channels} o={out_dim} {} {}",
                    act.name(),
                    if classify { "ce" } else { "mse" }
                );
                gradcheck_native(&label, &src, &params, &x, &y, &mut r);
            }
        }
    }
}

#[test]
fn prop_registered_native_models_pass_gradcheck() {
    // The three REGISTERED wire names (fixed architectures) must satisfy
    // the same finite-difference contract as the anonymous specs above —
    // this is the acceptance gate for the model/wire/checkpoint seam.
    for name in ["mlp_native", "linear_spiral_native", "conv1d_native"] {
        let nm = push::infer::native_model(name).unwrap();
        let spec = &nm.spec;
        // a tiny probe batch keeps the unit count low enough that a
        // kink-free ReLU draw exists with decent probability per attempt
        let b = if name == "conv1d_native" { 1 } else { 3 };
        let d: usize = spec.x_shape[1..].iter().product();
        let mut found = None;
        for case in 0..400u64 {
            let mut r = Rng::new(0x7265_6734).fold_in(case);
            let params = nm.init_params(case, 0);
            let x = Tensor::f32(vec![b, d], r.normal_vec(b * d));
            let margin = match name {
                "conv1d_native" => {
                    push::infer::models::CONV1D_NATIVE.min_abs_preactivation(&params, &x).unwrap()
                }
                "mlp_native" => {
                    push::infer::models::MLP_NATIVE.min_abs_preactivation(&params, &x).unwrap()
                }
                // depth 0: no hidden units, no kinks
                _ => f32::INFINITY,
            };
            if margin > 0.02 {
                found = Some((params, x, r));
                break;
            }
        }
        let (params, x, mut r) = found.expect("a kink-free draw exists in 400 cases");
        let y = if spec.task == "classify" {
            Tensor::i32(vec![b], (0..b).map(|_| r.below(2) as i32).collect())
        } else {
            let yn: usize = spec.y_shape[1..].iter().product();
            Tensor::f32(vec![b, yn], r.normal_vec(b * yn))
        };
        gradcheck_native(name, &nm.source, &params, &x, &y, &mut r);
    }
}

// ------------------------------------------------------------- kernels
//
// The kernel plane's hard invariant (DESIGN.md §14): scalar, SIMD, and
// thread-pool tiers run the SAME fixed-shape reduction tree, so every
// kernel returns bit-identical f32 results no matter which tier executed
// it. These tests pin that down over a shape grid chosen to hit every
// dispatch edge: empty, single element, below/at/above the 8-lane block
// width, ragged remainders (len % 8 != 0), and both sides of the
// PAR_MIN sharding threshold.
mod kernel_identity {
    use push::runtime::kernels::{self, Backend, PAR_MIN};
    use push::runtime::tensor::ops;
    use push::runtime::Tensor;
    use push::util::rng::Rng;
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// `force_backend` / `set_threads` are process-wide knobs. Serialize
    /// the tests that touch them; `Knobs` restores the defaults on drop
    /// (including on assertion panic, so one failure can't cascade).
    fn lock() -> MutexGuard<'static, ()> {
        static M: OnceLock<Mutex<()>> = OnceLock::new();
        M.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
    }

    struct Knobs;
    impl Drop for Knobs {
        fn drop(&mut self) {
            kernels::force_backend(None);
            kernels::set_threads(0);
        }
    }

    /// Every dispatch edge: 0, 1, ragged around the 8-lane width, ragged
    /// around 8-blocks, and both sides of the PAR_MIN shard threshold.
    const SHAPES: &[usize] =
        &[0, 1, 2, 7, 8, 9, 15, 16, 17, 63, 64, 65, 255, 1024, PAR_MIN, PAR_MIN + 1, 50_000];

    fn fill(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(0x6b72_6e6c).fold_in(seed).fold_in(n as u64);
        r.normal_vec(n)
    }

    /// One pass of every kernel over (seed, len): reduction results as
    /// bits, elementwise/composite outputs as bit vectors, all in one
    /// flat Vec<u32> so a single comparison covers the lot.
    fn all_kernel_bits(seed: u64, n: usize) -> Vec<u32> {
        let x = fill(seed, n);
        let y = fill(seed ^ 1, n);
        let z = fill(seed ^ 2, n);
        let mut bits = Vec::new();
        for v in [
            kernels::sum(&x),
            kernels::sum_sq(&x),
            kernels::dot(&x, &y),
            kernels::sq_dist(&x, &y),
            kernels::max(&x),
            kernels::mean(&x),
            kernels::l2_norm(&x),
        ] {
            bits.push(v.to_bits());
        }
        bits.push(kernels::argmax(&x) as u32);

        let mut buf = y.clone();
        kernels::axpy(&mut buf, 0.37, &x);
        bits.extend(buf.iter().map(|v| v.to_bits()));
        let mut buf = y.clone();
        kernels::scale(&mut buf, -1.25);
        bits.extend(buf.iter().map(|v| v.to_bits()));
        let mut buf = y.clone();
        kernels::div_scale(&mut buf, 3.0);
        bits.extend(buf.iter().map(|v| v.to_bits()));
        let mut buf = y.clone();
        kernels::scale_add(&mut buf, 0.9, 0.1, &x);
        bits.extend(buf.iter().map(|v| v.to_bits()));
        let mut buf = y.clone();
        kernels::scale_add_sq(&mut buf, 0.9, 0.1, &x);
        bits.extend(buf.iter().map(|v| v.to_bits()));
        let mut buf = y.clone();
        kernels::rbf_accum(&mut buf, 0.8, &x, 0.2, &z, &x);
        bits.extend(buf.iter().map(|v| v.to_bits()));

        let mut buf = x.clone();
        let (mx, zn) = kernels::softmax(&mut buf);
        bits.push(mx.to_bits());
        bits.push(zn.to_bits());
        bits.extend(buf.iter().map(|v| v.to_bits()));
        let mut buf = x.clone();
        let margin = kernels::act_margin(&mut buf, |v| v.max(0.0));
        bits.push(margin.to_bits());
        bits.extend(buf.iter().map(|v| v.to_bits()));
        bits
    }

    #[test]
    fn prop_kernels_bit_identical_across_backends_and_threads() {
        let _g = lock();
        let _restore = Knobs;
        for &n in SHAPES {
            for seed in 0..3u64 {
                kernels::force_backend(Some(Backend::Scalar));
                kernels::set_threads(1);
                let reference = all_kernel_bits(seed, n);
                for backend in kernels::available_backends() {
                    for threads in [1usize, 4] {
                        kernels::force_backend(Some(backend));
                        kernels::set_threads(threads);
                        let got = all_kernel_bits(seed, n);
                        assert!(
                            got == reference,
                            "len {n} seed {seed}: {backend:?} x {threads} threads \
                             diverged from the scalar reference"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn prop_gemv_scatter_bit_identical_across_backends_and_threads() {
        let _g = lock();
        let _restore = Knobs;
        // (din, dout) pairs: scalar-sized, lane-ragged, full blocks, and a
        // dout big enough that each scatter row crosses several 8-blocks
        for (din, dout) in [(1usize, 1usize), (3, 5), (8, 8), (17, 9), (7, 130)] {
            let x = fill(din as u64, din);
            let w = fill((din * dout) as u64, din * dout);
            kernels::force_backend(Some(Backend::Scalar));
            kernels::set_threads(1);
            let mut reference = vec![0.5f32; dout];
            kernels::gemv_scatter(&mut reference, &x, &w);
            for backend in kernels::available_backends() {
                for threads in [1usize, 4] {
                    kernels::force_backend(Some(backend));
                    kernels::set_threads(threads);
                    let mut got = vec![0.5f32; dout];
                    kernels::gemv_scatter(&mut got, &x, &w);
                    let same = got
                        .iter()
                        .zip(&reference)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same, "gemv {din}x{dout}: {backend:?} x {threads} diverged");
                }
            }
        }
    }

    #[test]
    fn prop_kernels_propagate_nan_and_inf_identically() {
        let _g = lock();
        let _restore = Knobs;
        // Special values must flow through every tier the same way: the
        // reductions go NaN/inf, max ignores NaN via f32::max on every
        // path, and elementwise ops propagate per element. Bit-compare the
        // whole battery with specials planted at lane 0, a ragged-tail
        // lane, and mid-shard positions.
        for &n in &[9usize, 64, 1024, PAR_MIN + 7] {
            let mut x = fill(0x5eed, n);
            x[0] = f32::NAN;
            x[n / 2] = f32::INFINITY;
            x[n - 1] = f32::NEG_INFINITY;
            let y = fill(0x5eee, n);
            let run = || {
                let mut bits = vec![
                    kernels::sum(&x).to_bits(),
                    kernels::dot(&x, &y).to_bits(),
                    kernels::sq_dist(&x, &y).to_bits(),
                    kernels::max(&x).to_bits(),
                    kernels::l2_norm(&x).to_bits(),
                ];
                let mut buf = y.clone();
                kernels::axpy(&mut buf, 2.0, &x);
                bits.extend(buf.iter().map(|v| v.to_bits()));
                bits
            };
            kernels::force_backend(Some(Backend::Scalar));
            kernels::set_threads(1);
            let reference = run();
            assert!(f32::from_bits(reference[0]).is_nan(), "sum must be NaN");
            assert_eq!(f32::from_bits(reference[3]), f32::INFINITY, "max skips NaN");
            for backend in kernels::available_backends() {
                for threads in [1usize, 4] {
                    kernels::force_backend(Some(backend));
                    kernels::set_threads(threads);
                    assert!(
                        run() == reference,
                        "len {n}: {backend:?} x {threads} diverged on NaN/inf input"
                    );
                }
            }
        }
    }

    /// One full native-MLP step — forward, cross-entropy backward, and the
    /// -lr drift applied through `ops` — bit-compared between the scalar
    /// 1-thread tier and the widest available backend at 4 threads. This
    /// is the end-to-end seal on top of the per-kernel grid: the whole
    /// consumer chain (models.rs + tensor.rs ops) stays placement- and
    /// dispatch-invariant.
    #[test]
    fn prop_native_mlp_step_bit_identical_kernels_on_vs_off() {
        let _g = lock();
        let _restore = Knobs;
        let nm = push::infer::native_model("mlp_native").unwrap();
        let push::infer::ModelSource::Native { grad, .. } = &nm.source else {
            panic!("mlp_native is a native source")
        };
        let d: usize = nm.spec.x_shape[1..].iter().product();
        let b = 16usize;
        let step = |seed: u64| -> (u32, Vec<u32>, Vec<u32>) {
            let mut r = Rng::new(0x5349_4d44).fold_in(seed);
            let params = nm.init_params(seed, 0);
            let x = Tensor::f32(vec![b, d], r.normal_vec(b * d));
            let y = Tensor::i32(vec![b], (0..b).map(|_| r.below(2) as i32).collect());
            let (loss, g) = grad(&params, &x, &y).expect("native grad");
            let mut p = params.clone();
            ops::axpy(&mut p, -0.05, &g);
            (
                loss.to_bits(),
                g.as_f32().iter().map(|v| v.to_bits()).collect(),
                p.as_f32().iter().map(|v| v.to_bits()).collect(),
            )
        };
        for seed in 0..8u64 {
            kernels::force_backend(Some(Backend::Scalar));
            kernels::set_threads(1);
            let want = step(seed);
            kernels::force_backend(None);
            kernels::set_threads(4);
            let got = step(seed);
            assert!(
                got == want,
                "seed {seed}: full MLP step diverged between scalar x1 and \
                 default backend x4 (loss bits {:#010x} vs {:#010x})",
                want.0,
                got.0
            );
        }
    }
}
