//! Concurrency stress tests: message storms, deep cache pressure, and
//! deadlock containment. These are the failure modes the paper's NEL
//! design (§4.2) must survive.
//!
//! The scheduler tests (top half) are hermetic — parameter-less particles,
//! no artifacts, no PJRT — and pin down the M:N control plane's contract:
//! OS thread count stays O(workers + devices) for O(1000) particles,
//! per-particle mailbox FIFO, handler non-reentrancy, and blocked-worker
//! compensation for leader/follower wait DAGs on a small pool.
//!
//! The artifact-backed tests (bottom) additionally require `make
//! artifacts` and a `--features pjrt` build.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use push::device::CostModel;
use push::nel::CreateOpts;
use push::particle::{handler, PFuture, Value};
use push::runtime::{DType, ModelSpec};
use push::{Nel, NelConfig, Pid};

fn sched_cfg(devices: usize, workers: usize) -> NelConfig {
    NelConfig {
        num_devices: devices,
        cache_size: 4,
        cost: CostModel::free(),
        control_workers: workers,
        seed: 1,
        ..NelConfig::default()
    }
}

/// A parameter-less model spec: the scheduler tests exercise the control
/// plane only, so no artifacts are involved.
fn dummy_model() -> Arc<ModelSpec> {
    Arc::new(ModelSpec {
        name: "sched_stress_dummy".to_string(),
        param_count: 0,
        task: "regress".to_string(),
        x_shape: vec![1],
        y_shape: vec![1],
        y_dtype: DType::F32,
        arch: "none".to_string(),
        meta: BTreeMap::new(),
        entries: BTreeMap::new(),
    })
}

/// Current OS thread count of this process (Linux); None elsewhere.
fn os_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

fn no_params_particle(
    nel: &Nel,
    model: &Arc<ModelSpec>,
    msg: &str,
    h: push::particle::Handler,
) -> Pid {
    nel.p_create(
        model.clone(),
        CreateOpts {
            no_params: true,
            receive: [(msg.to_string(), h)].into_iter().collect(),
            ..CreateOpts::default()
        },
    )
    .unwrap()
}

/// The headline scale test: 1024 particles on a 16-worker pool across 2
/// devices run a full broadcast round. With thread-per-particle this
/// process would gain ~1024 threads; the M:N scheduler keeps the delta at
/// O(workers) (bounds below are generous because other tests in this
/// binary run concurrently and own their own pools).
#[test]
fn thousand_particles_bounded_threads_full_round() {
    const N: usize = 1024;
    const WORKERS: usize = 16;
    let nel = Nel::new(sched_cfg(2, WORKERS)).unwrap();
    let after_pool = os_threads();

    let hits = Arc::new(AtomicUsize::new(0));
    let h = hits.clone();
    let ping = handler(move |ctx, _| {
        h.fetch_add(1, Ordering::Relaxed);
        Ok(Value::Usize(ctx.pid.0 as usize))
    });
    let model = dummy_model();
    let pids: Vec<Pid> = (0..N)
        .map(|_| no_params_particle(&nel, &model, "PING", ping.clone()))
        .collect();

    // Particle creation spawns NO threads. (Noise tolerance: sibling
    // tests may be mid-setup; thread-per-particle would add exactly N.)
    if let (Some(t1), Some(t2)) = (after_pool, os_threads()) {
        let delta = t2.saturating_sub(t1);
        assert!(
            delta < N / 4,
            "creating {N} particles grew the process by {delta} threads — \
             particle creation must not spawn threads"
        );
    }

    // Full message round via batched fan-out; everything must resolve.
    let futs = nel.broadcast(None, &pids, "PING", vec![]);
    assert_eq!(futs.len(), N);
    let vals = PFuture::join_all(&futs)
        .wait_timeout(Duration::from_secs(120))
        .expect("broadcast round deadlocked")
        .unwrap()
        .list()
        .unwrap();
    for (v, p) in vals.iter().zip(&pids) {
        assert_eq!(*v, Value::Usize(p.0 as usize));
    }
    assert_eq!(hits.load(Ordering::Relaxed), N);

    // The worker pool is bounded even after the round: live workers never
    // exceed the compensation cap, and the OS thread delta stays
    // O(workers + devices), not O(particles).
    let stats = nel.stats();
    assert_eq!(stats.msgs_sent, N as u64);
    assert_eq!(stats.sched.handler_runs, N as u64);
    assert_eq!(stats.sched.pool_target, WORKERS);
    assert!(
        stats.sched.workers_peak <= stats.sched.max_workers,
        "peak {} exceeded cap {}",
        stats.sched.workers_peak,
        stats.sched.max_workers
    );
    if let (Some(t1), Some(t3)) = (after_pool, os_threads()) {
        let delta = t3.saturating_sub(t1);
        assert!(
            delta < N / 4,
            "after the round the process grew by {delta} threads for {N} particles"
        );
    }
}

/// Leader/follower wait DAG on a deliberately tiny pool: the leader's
/// handler blocks mid-execution on all 256 followers' replies, so the
/// scheduler MUST compensate for the blocked worker or the round
/// deadlocks (followers could never be scheduled on a saturated pool).
#[test]
fn leader_follower_wait_dag_on_small_pool() {
    let nel = Nel::new(sched_cfg(2, 4)).unwrap();
    let model = dummy_model();
    let work = handler(|ctx, _| {
        // busy (not future-blocked) long enough that the leader's wait
        // reliably observes a pending join
        std::thread::sleep(Duration::from_micros(200));
        Ok(Value::Usize(ctx.pid.0 as usize))
    });
    let followers: Vec<Pid> = (0..256)
        .map(|_| no_params_particle(&nel, &model, "WORK", work.clone()))
        .collect();
    let fls = followers.clone();
    let round = handler(move |ctx, _| {
        let futs = ctx.broadcast(&fls, "WORK", vec![]);
        let vals = PFuture::join_all(&futs).wait()?.list()?;
        Ok(Value::Usize(vals.len()))
    });
    let leader = no_params_particle(&nel, &model, "ROUND", round);

    for r in 0..3 {
        let got = nel
            .send(None, leader, "ROUND", vec![])
            .wait_timeout(Duration::from_secs(60))
            .unwrap_or_else(|| panic!("wait-DAG round {r} deadlocked"))
            .unwrap();
        assert_eq!(got, Value::Usize(followers.len()));
    }
    let stats = nel.stats();
    assert!(
        stats.sched.compensations >= 1,
        "blocked leader never triggered compensation: {:?}",
        stats.sched
    );
    assert!(stats.sched.workers_peak <= stats.sched.max_workers);
}

/// Per-particle mailbox FIFO survives the M:N scheduler: 500 sequenced
/// messages from one sender arrive in order (batched drains included).
#[test]
fn mailbox_fifo_per_particle_preserved() {
    let nel = Nel::new(sched_cfg(1, 8)).unwrap();
    let model = dummy_model();
    let seq = handler(|ctx, args| {
        let i = args[0].usize()?;
        let mut got = match ctx.state_take("seq") {
            Some(Value::List(v)) => v,
            _ => Vec::new(),
        };
        got.push(Value::Usize(i));
        ctx.state_set("seq", Value::List(got));
        Ok(Value::Unit)
    });
    let read = handler(|ctx, _| Ok(ctx.state_get("seq").unwrap_or(Value::List(Vec::new()))));
    let p = nel
        .p_create(
            model,
            CreateOpts {
                no_params: true,
                receive: [
                    ("SEQ".to_string(), seq),
                    ("READ".to_string(), read),
                ]
                .into_iter()
                .collect(),
                ..CreateOpts::default()
            },
        )
        .unwrap();

    const N: usize = 500;
    let futs: Vec<PFuture> = (0..N)
        .map(|i| nel.send(None, p, "SEQ", vec![Value::Usize(i)]))
        .collect();
    PFuture::join_all(&futs)
        .wait_timeout(Duration::from_secs(60))
        .expect("sequence stalled")
        .unwrap();
    let got = nel.send(None, p, "READ", vec![]).wait().unwrap().list().unwrap();
    assert_eq!(got.len(), N);
    for (i, v) in got.iter().enumerate() {
        assert_eq!(*v, Value::Usize(i), "mailbox FIFO violated at {i}");
    }
}

/// Handler non-reentrancy: a 4-thread driver storm against ONE particle
/// must never observe two of its handlers in flight at once.
#[test]
fn handlers_never_run_concurrently_for_one_particle() {
    let nel = Nel::new(sched_cfg(2, 8)).unwrap();
    let model = dummy_model();
    let active = Arc::new(AtomicUsize::new(0));
    let violations = Arc::new(AtomicUsize::new(0));
    let (a, v) = (active.clone(), violations.clone());
    let h = handler(move |_ctx, _| {
        if a.fetch_add(1, Ordering::SeqCst) != 0 {
            v.fetch_add(1, Ordering::SeqCst);
        }
        std::thread::sleep(Duration::from_micros(100));
        a.fetch_sub(1, Ordering::SeqCst);
        Ok(Value::Unit)
    });
    let p = no_params_particle(&nel, &model, "HIT", h);

    let mut drivers = Vec::new();
    for _ in 0..4 {
        let nel2 = nel.clone();
        drivers.push(std::thread::spawn(move || {
            let futs: Vec<PFuture> =
                (0..100).map(|_| nel2.send(None, p, "HIT", vec![])).collect();
            PFuture::join_all(&futs)
                .wait_timeout(Duration::from_secs(60))
                .expect("storm deadlocked")
                .unwrap();
        }));
    }
    for d in drivers {
        d.join().unwrap();
    }
    assert_eq!(violations.load(Ordering::SeqCst), 0, "handler ran reentrantly");
    assert_eq!(nel.stats().sched.handler_runs, 400);
}

/// Handlers blocking on device-job futures (the common `ctx.step().wait()`
/// shape, here simulated with cross-particle sends) drain fully on a tiny
/// pool — compensation keeps the pool live without ballooning past its cap.
#[test]
fn chained_sends_on_tiny_pool_resolve() {
    let nel = Nel::new(sched_cfg(1, 2)).unwrap();
    let model = dummy_model();
    let sink = handler(|_ctx, _| Ok(Value::Usize(1)));
    let sinks: Vec<Pid> = (0..8)
        .map(|_| no_params_particle(&nel, &model, "SINK", sink.clone()))
        .collect();
    let targets = sinks.clone();
    let relay = handler(move |ctx, args| {
        // block mid-handler on another particle's handler (depth-1 DAG)
        let i = args[0].usize()?;
        ctx.send(targets[i % targets.len()], "SINK", vec![]).wait()
    });
    let relays: Vec<Pid> = (0..64)
        .map(|_| no_params_particle(&nel, &model, "RELAY", relay.clone()))
        .collect();

    let futs: Vec<PFuture> = relays
        .iter()
        .enumerate()
        .map(|(i, p)| nel.send(None, *p, "RELAY", vec![Value::Usize(i)]))
        .collect();
    let vals = PFuture::join_all(&futs)
        .wait_timeout(Duration::from_secs(60))
        .expect("relay storm deadlocked")
        .unwrap()
        .list()
        .unwrap();
    assert_eq!(vals.len(), 64);
    let stats = nel.stats();
    assert!(stats.sched.workers_peak <= stats.sched.max_workers);
}

/// The adversarial shape for bounded compensation: 32 chains of depth 2
/// (root waits on mid, mid waits on leaf), far wider than the worker cap
/// of a 2-worker pool (2*4+4 = 12). Once every live worker is blocked the
/// pool cannot grow; blocked workers must HELP drain the dependency lane
/// themselves or the leaves strand and this hangs forever. Slow leaves
/// keep chains in flight so the cap is actually reached.
#[test]
fn deep_wide_wait_chains_resolve_at_worker_cap() {
    const W: usize = 32;
    let nel = Nel::new(sched_cfg(1, 2)).unwrap();
    let model = dummy_model();
    let leaf = handler(|ctx, _| {
        std::thread::sleep(Duration::from_millis(5));
        Ok(Value::Usize(ctx.pid.0 as usize))
    });
    let leaves: Vec<Pid> = (0..W)
        .map(|_| no_params_particle(&nel, &model, "LEAF", leaf.clone()))
        .collect();
    let l2 = leaves.clone();
    let mid = handler(move |ctx, args| {
        let i = args[0].usize()?;
        ctx.send(l2[i], "LEAF", vec![]).wait()
    });
    let mids: Vec<Pid> = (0..W)
        .map(|_| no_params_particle(&nel, &model, "MID", mid.clone()))
        .collect();
    let m2 = mids.clone();
    let root = handler(move |ctx, args| {
        let i = args[0].usize()?;
        ctx.send(m2[i], "MID", vec![Value::Usize(i)]).wait()
    });
    let roots: Vec<Pid> = (0..W)
        .map(|_| no_params_particle(&nel, &model, "ROOT", root.clone()))
        .collect();

    let futs: Vec<PFuture> = roots
        .iter()
        .enumerate()
        .map(|(i, p)| nel.send(None, *p, "ROOT", vec![Value::Usize(i)]))
        .collect();
    let vals = PFuture::join_all(&futs)
        .wait_timeout(Duration::from_secs(120))
        .expect("depth-2 chain wave deadlocked at the worker cap")
        .unwrap()
        .list()
        .unwrap();
    for (v, c) in vals.iter().zip(&leaves) {
        assert_eq!(*v, Value::Usize(c.0 as usize));
    }
    let stats = nel.stats();
    assert!(
        stats.sched.workers_peak <= stats.sched.max_workers,
        "pool grew past its cap: {:?}",
        stats.sched
    );
}

/// A dependency that lives on a SHARD (driver-scheduled, not in the
/// priority lane) must stay reachable when every live worker is blocked:
/// 20 roots on a 1-worker pool (cap 8) all block on one shared gate
/// future; the particle that completes the gate is then scheduled by a
/// driver send. Shard FIFO admits every root before the release particle,
/// so by the time it can run, the pool is saturated — only a blocked
/// worker in helping mode can pop it off the shard.
#[test]
fn shard_queued_dependency_reachable_at_worker_cap() {
    const ROOTS: usize = 20;
    let nel = Nel::new(sched_cfg(1, 1)).unwrap();
    let model = dummy_model();
    let gate = PFuture::new();
    let g = gate.clone();
    let waiter = handler(move |_ctx, _| g.wait());
    let roots: Vec<Pid> = (0..ROOTS)
        .map(|_| no_params_particle(&nel, &model, "WAIT", waiter.clone()))
        .collect();
    let g = gate.clone();
    let release = handler(move |_ctx, _| {
        g.complete(Ok(Value::Usize(42)));
        Ok(Value::Unit)
    });
    let releaser = no_params_particle(&nel, &model, "RELEASE", release);

    let futs: Vec<PFuture> = roots
        .iter()
        .map(|p| nel.send(None, *p, "WAIT", vec![]))
        .collect();
    // Give the pool time to saturate on the gate, then schedule the
    // releasing particle through the normal (shard) path.
    std::thread::sleep(Duration::from_millis(50));
    let rel = nel.send(None, releaser, "RELEASE", vec![]);
    let vals = PFuture::join_all(&futs)
        .wait_timeout(Duration::from_secs(60))
        .expect("shard-queued dependency stranded behind a saturated pool")
        .unwrap()
        .list()
        .unwrap();
    assert_eq!(vals.len(), ROOTS);
    for v in vals {
        assert_eq!(v, Value::Usize(42));
    }
    rel.wait_timeout(Duration::from_secs(10)).expect("release hung").unwrap();
    let stats = nel.stats();
    assert!(
        stats.sched.helps >= 1,
        "saturated pool resolved without helping — scheduling hole: {:?}",
        stats.sched
    );
}

// ---- artifact-backed stress (requires `make artifacts` + --features pjrt)

#[cfg(feature = "pjrt")]
mod with_artifacts {
    use std::time::Duration;

    use push::device::CostModel;
    use push::nel::CreateOpts;
    use push::particle::{handler, PFuture, Value};
    use push::runtime::{artifacts_dir, Manifest, Tensor};
    use push::util::rng::Rng;
    use push::{NelConfig, PushDist};

    fn manifest() -> Manifest {
        Manifest::load(artifacts_dir()).expect("run `make artifacts` before cargo test")
    }

    fn cfg(devices: usize, cache: usize) -> NelConfig {
        NelConfig {
            num_devices: devices,
            cache_size: cache,
            cost: CostModel::free(),
            seed: 1,
            ..NelConfig::default()
        }
    }

    #[test]
    fn many_particles_tiny_cache_message_storm() {
        // 24 particles on 2 devices with 2 cache slots each; fire interleaved
        // STEP and GET messages from the driver and random cross-particle GETs
        // from handlers. Everything must resolve; parameters stay intact.
        let m = manifest();
        let pd = PushDist::new(&m, "mlp_tiny", cfg(2, 2)).unwrap();
        let peek = handler(|ctx, args| {
            // read a random other particle's params (cross-particle traffic)
            let target = push::Pid(args[0].usize()? as u32);
            let t = ctx.get(target).wait()?.tensor()?;
            Ok(Value::Usize(t.element_count()))
        });
        let step = handler(|ctx, args| {
            let x = args[0].as_tensor()?.clone();
            let y = args[1].as_tensor()?.clone();
            ctx.step(x, y, 0.01).wait()
        });
        let n = 24usize;
        let pids = pd
            .p_create_n(n, |_| CreateOpts {
                receive: [
                    ("PEEK".to_string(), peek.clone()),
                    ("STEP".to_string(), step.clone()),
                ]
                .into_iter()
                .collect(),
                ..CreateOpts::default()
            })
            .unwrap();

        let model = pd.model().clone();
        let mut rng = Rng::new(7);
        let xn: usize = model.x_shape.iter().product();
        let yn: usize = model.y_shape.iter().product();
        let x = Tensor::f32(model.x_shape.clone(), rng.normal_vec(xn));
        let y = Tensor::f32(model.y_shape.clone(), rng.normal_vec(yn));

        let mut futs: Vec<PFuture> = Vec::new();
        for round in 0..6 {
            for (i, p) in pids.iter().enumerate() {
                if (i + round) % 3 == 0 {
                    let target = pids[rng.below(n)];
                    futs.push(pd.p_launch(*p, "PEEK", vec![Value::Usize(target.0 as usize)]));
                } else {
                    futs.push(pd.p_launch(
                        *p,
                        "STEP",
                        vec![Value::Tensor(x.clone()), Value::Tensor(y.clone()), Value::F32(0.01)],
                    ));
                }
            }
        }
        for (i, f) in futs.iter().enumerate() {
            let r = f
                .wait_timeout(Duration::from_secs(120))
                .unwrap_or_else(|| panic!("future {i} did not resolve (deadlock?)"));
            r.unwrap();
        }
        let stats = pd.stats();
        let d0 = &stats.devices[0];
        assert!(d0.swaps_out > 0, "expected heavy cache churn");
        // all parameters intact after the storm
        let snap = pd.drain_params().unwrap();
        assert_eq!(snap.len(), n);
        for t in snap.values() {
            assert!(t.as_f32().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn handler_chains_across_devices_resolve() {
        // A -> B -> C chained sends across 3 devices (waits form a DAG).
        let m = manifest();
        let pd = PushDist::new(&m, "mlp_tiny", cfg(3, 2)).unwrap();
        let hop = handler(|ctx, args| {
            let chain = args[0].clone().list()?;
            if chain.is_empty() {
                return Ok(Value::Usize(ctx.pid.0 as usize));
            }
            let next = push::Pid(chain[0].usize()? as u32);
            let rest = Value::List(chain[1..].to_vec());
            let got = ctx.send(next, "HOP", vec![rest]).wait()?;
            Ok(Value::List(vec![Value::Usize(ctx.pid.0 as usize), got]))
        });
        let pids = pd
            .p_create_n(3, |_| CreateOpts {
                receive: [("HOP".to_string(), hop.clone())].into_iter().collect(),
                ..CreateOpts::default()
            })
            .unwrap();
        let chain = Value::List(vec![
            Value::Usize(pids[1].0 as usize),
            Value::Usize(pids[2].0 as usize),
        ]);
        let out = pd
            .p_launch(pids[0], "HOP", vec![chain])
            .wait_timeout(Duration::from_secs(60))
            .expect("chain deadlocked")
            .unwrap();
        // nested [0, [1, 2]]
        let lvl0 = out.list().unwrap();
        assert_eq!(lvl0[0], Value::Usize(pids[0].0 as usize));
        let lvl1 = lvl0[1].clone().list().unwrap();
        assert_eq!(lvl1[0], Value::Usize(pids[1].0 as usize));
        assert_eq!(lvl1[1], Value::Usize(pids[2].0 as usize));
    }

    #[test]
    fn failures_do_not_poison_other_particles() {
        // One particle panics on every message; its neighbors keep training.
        let m = manifest();
        let pd = PushDist::new(&m, "mlp_tiny", cfg(1, 2)).unwrap();
        let boom = handler(|_ctx, _| panic!("chaos"));
        let step = handler(|ctx, args| {
            let x = args[0].as_tensor()?.clone();
            let y = args[1].as_tensor()?.clone();
            ctx.step(x, y, 0.01).wait()
        });
        let bad = pd
            .p_create(CreateOpts {
                receive: [("STEP".to_string(), boom)].into_iter().collect(),
                ..CreateOpts::default()
            })
            .unwrap();
        let good = pd
            .p_create(CreateOpts {
                receive: [("STEP".to_string(), step)].into_iter().collect(),
                ..CreateOpts::default()
            })
            .unwrap();
        let model = pd.model().clone();
        let mut rng = Rng::new(3);
        let xn: usize = model.x_shape.iter().product();
        let yn: usize = model.y_shape.iter().product();
        let x = Tensor::f32(model.x_shape.clone(), rng.normal_vec(xn));
        let y = Tensor::f32(model.y_shape.clone(), rng.normal_vec(yn));
        let args = || vec![Value::Tensor(x.clone()), Value::Tensor(y.clone()), Value::F32(0.01)];

        for _ in 0..5 {
            assert!(pd.p_launch(bad, "STEP", args()).wait().is_err());
            assert!(pd.p_launch(good, "STEP", args()).wait().is_ok());
        }
        assert_eq!(pd.stats().handler_errors, 5);
    }

    #[test]
    fn device_pinning_respected_and_out_of_range_rejected() {
        let m = manifest();
        let pd = PushDist::new(&m, "mlp_tiny", cfg(2, 2)).unwrap();
        let a = pd.p_create(CreateOpts { device: Some(1), ..CreateOpts::default() }).unwrap();
        assert_eq!(pd.nel().device_of(a), Some(1));
        let err = pd.p_create(CreateOpts { device: Some(9), ..CreateOpts::default() });
        assert!(err.is_err());
    }

    #[test]
    fn no_params_particles_carry_state_only() {
        // The paper §C.2 floats encoding SWAG moments as extra particles; a
        // particle can be created without parameters and still serve messages.
        let m = manifest();
        let pd = PushDist::new(&m, "mlp_tiny", cfg(1, 2)).unwrap();
        let bump = handler(|ctx, _| {
            let n = match ctx.state_get("count") {
                Some(Value::Usize(n)) => n,
                _ => 0,
            };
            ctx.state_set("count", Value::Usize(n + 1));
            Ok(Value::Usize(n + 1))
        });
        let p = pd
            .p_create(CreateOpts {
                no_params: true,
                receive: [("BUMP".to_string(), bump)].into_iter().collect(),
                state: vec![("count".to_string(), Value::Usize(10))],
                ..CreateOpts::default()
            })
            .unwrap();
        for want in 11..=13 {
            let got = pd.p_launch(p, "BUMP", vec![]).wait().unwrap();
            assert_eq!(got, Value::Usize(want));
        }
        // reading its (nonexistent) params errors but does not crash
        assert!(pd.get(p).wait().is_err());
    }
}
