//! Concurrency stress tests: message storms, deep cache pressure, and
//! deadlock containment over real artifacts. These are the failure modes
//! the paper's NEL design (§4.2) must survive.
//! Requires `make artifacts` and a `--features pjrt` build.
#![cfg(feature = "pjrt")]

use std::time::Duration;

use push::device::CostModel;
use push::nel::CreateOpts;
use push::particle::{handler, PFuture, Value};
use push::runtime::{artifacts_dir, Manifest, Tensor};
use push::util::rng::Rng;
use push::{NelConfig, PushDist};

fn manifest() -> Manifest {
    Manifest::load(artifacts_dir()).expect("run `make artifacts` before cargo test")
}

fn cfg(devices: usize, cache: usize) -> NelConfig {
    NelConfig {
        num_devices: devices,
        cache_size: cache,
        cost: CostModel::free(),
        seed: 1,
        ..NelConfig::default()
    }
}

#[test]
fn many_particles_tiny_cache_message_storm() {
    // 24 particles on 2 devices with 2 cache slots each; fire interleaved
    // STEP and GET messages from the driver and random cross-particle GETs
    // from handlers. Everything must resolve; parameters stay intact.
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(2, 2)).unwrap();
    let peek = handler(|ctx, args| {
        // read a random other particle's params (cross-particle traffic)
        let target = push::Pid(args[0].usize()? as u32);
        let t = ctx.get(target).wait()?.tensor()?;
        Ok(Value::Usize(t.element_count()))
    });
    let step = handler(|ctx, args| {
        let x = args[0].as_tensor()?.clone();
        let y = args[1].as_tensor()?.clone();
        ctx.step(x, y, 0.01).wait()
    });
    let n = 24usize;
    let pids = pd
        .p_create_n(n, |_| CreateOpts {
            receive: [
                ("PEEK".to_string(), peek.clone()),
                ("STEP".to_string(), step.clone()),
            ]
            .into_iter()
            .collect(),
            ..CreateOpts::default()
        })
        .unwrap();

    let model = pd.model().clone();
    let mut rng = Rng::new(7);
    let xn: usize = model.x_shape.iter().product();
    let yn: usize = model.y_shape.iter().product();
    let x = Tensor::f32(model.x_shape.clone(), rng.normal_vec(xn));
    let y = Tensor::f32(model.y_shape.clone(), rng.normal_vec(yn));

    let mut futs: Vec<PFuture> = Vec::new();
    for round in 0..6 {
        for (i, p) in pids.iter().enumerate() {
            if (i + round) % 3 == 0 {
                let target = pids[rng.below(n)];
                futs.push(pd.p_launch(*p, "PEEK", vec![Value::Usize(target.0 as usize)]));
            } else {
                futs.push(pd.p_launch(
                    *p,
                    "STEP",
                    vec![Value::Tensor(x.clone()), Value::Tensor(y.clone()), Value::F32(0.01)],
                ));
            }
        }
    }
    for (i, f) in futs.iter().enumerate() {
        let r = f
            .wait_timeout(Duration::from_secs(120))
            .unwrap_or_else(|| panic!("future {i} did not resolve (deadlock?)"));
        r.unwrap();
    }
    let stats = pd.stats();
    let d0 = &stats.devices[0];
    assert!(d0.swaps_out > 0, "expected heavy cache churn");
    // all parameters intact after the storm
    let snap = pd.drain_params().unwrap();
    assert_eq!(snap.len(), n);
    for t in snap.values() {
        assert!(t.as_f32().iter().all(|v| v.is_finite()));
    }
}

#[test]
fn handler_chains_across_devices_resolve() {
    // A -> B -> C chained sends across 3 devices (waits form a DAG).
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(3, 2)).unwrap();
    let hop = handler(|ctx, args| {
        let chain = args[0].clone().list()?;
        if chain.is_empty() {
            return Ok(Value::Usize(ctx.pid.0 as usize));
        }
        let next = push::Pid(chain[0].usize()? as u32);
        let rest = Value::List(chain[1..].to_vec());
        let got = ctx.send(next, "HOP", vec![rest]).wait()?;
        Ok(Value::List(vec![Value::Usize(ctx.pid.0 as usize), got]))
    });
    let pids = pd
        .p_create_n(3, |_| CreateOpts {
            receive: [("HOP".to_string(), hop.clone())].into_iter().collect(),
            ..CreateOpts::default()
        })
        .unwrap();
    let chain = Value::List(vec![
        Value::Usize(pids[1].0 as usize),
        Value::Usize(pids[2].0 as usize),
    ]);
    let out = pd
        .p_launch(pids[0], "HOP", vec![chain])
        .wait_timeout(Duration::from_secs(60))
        .expect("chain deadlocked")
        .unwrap();
    // nested [0, [1, 2]]
    let lvl0 = out.list().unwrap();
    assert_eq!(lvl0[0], Value::Usize(pids[0].0 as usize));
    let lvl1 = lvl0[1].clone().list().unwrap();
    assert_eq!(lvl1[0], Value::Usize(pids[1].0 as usize));
    assert_eq!(lvl1[1], Value::Usize(pids[2].0 as usize));
}

#[test]
fn failures_do_not_poison_other_particles() {
    // One particle panics on every message; its neighbors keep training.
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(1, 2)).unwrap();
    let boom = handler(|_ctx, _| panic!("chaos"));
    let step = handler(|ctx, args| {
        let x = args[0].as_tensor()?.clone();
        let y = args[1].as_tensor()?.clone();
        ctx.step(x, y, 0.01).wait()
    });
    let bad = pd
        .p_create(CreateOpts {
            receive: [("STEP".to_string(), boom)].into_iter().collect(),
            ..CreateOpts::default()
        })
        .unwrap();
    let good = pd
        .p_create(CreateOpts {
            receive: [("STEP".to_string(), step)].into_iter().collect(),
            ..CreateOpts::default()
        })
        .unwrap();
    let model = pd.model().clone();
    let mut rng = Rng::new(3);
    let xn: usize = model.x_shape.iter().product();
    let yn: usize = model.y_shape.iter().product();
    let x = Tensor::f32(model.x_shape.clone(), rng.normal_vec(xn));
    let y = Tensor::f32(model.y_shape.clone(), rng.normal_vec(yn));
    let args = || vec![Value::Tensor(x.clone()), Value::Tensor(y.clone()), Value::F32(0.01)];

    for _ in 0..5 {
        assert!(pd.p_launch(bad, "STEP", args()).wait().is_err());
        assert!(pd.p_launch(good, "STEP", args()).wait().is_ok());
    }
    assert_eq!(pd.stats().handler_errors, 5);
}

#[test]
fn device_pinning_respected_and_out_of_range_rejected() {
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(2, 2)).unwrap();
    let a = pd.p_create(CreateOpts { device: Some(1), ..CreateOpts::default() }).unwrap();
    assert_eq!(pd.nel().device_of(a), Some(1));
    let err = pd.p_create(CreateOpts { device: Some(9), ..CreateOpts::default() });
    assert!(err.is_err());
}

#[test]
fn no_params_particles_carry_state_only() {
    // The paper §C.2 floats encoding SWAG moments as extra particles; a
    // particle can be created without parameters and still serve messages.
    let m = manifest();
    let pd = PushDist::new(&m, "mlp_tiny", cfg(1, 2)).unwrap();
    let bump = handler(|ctx, _| {
        let n = match ctx.state_get("count") {
            Some(Value::Usize(n)) => n,
            _ => 0,
        };
        ctx.state_set("count", Value::Usize(n + 1));
        Ok(Value::Usize(n + 1))
    });
    let p = pd
        .p_create(CreateOpts {
            no_params: true,
            receive: [("BUMP".to_string(), bump)].into_iter().collect(),
            state: vec![("count".to_string(), Value::Usize(10))],
            ..CreateOpts::default()
        })
        .unwrap();
    for want in 11..=13 {
        let got = pd.p_launch(p, "BUMP", vec![]).wait().unwrap();
        assert_eq!(got, Value::Usize(want));
    }
    // reading its (nonexistent) params errors but does not crash
    assert!(pd.get(p).wait().is_err());
}
