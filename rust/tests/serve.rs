//! Serving-under-load stress tests (hermetic: native linear model, no
//! artifacts, no PJRT; the TCP case uses real 127.0.0.1 sockets).
//!
//! The acceptance bar of the pipelined-data + serving subsystem
//! (DESIGN.md §10):
//! * hammering `PosteriorServer::predict_mean` from 8 threads while SGLD
//!   trains 64 particles on the M:N scheduler neither panics nor
//!   deadlocks;
//! * every snapshot a reader takes is a COMPLETE reservoir version —
//!   `samples.len() == min(seen, cap)` for every chain, never a torn
//!   mid-commit mix (the chain handler commits `(samples, seen)`
//!   atomically);
//! * training under full serve traffic produces BIT-IDENTICAL losses and
//!   final parameters to a run with zero traffic — serving reads
//!   snapshots, it never perturbs chains;
//! * a snapshot taken over TCP (`spawn_loopback_node`-backed fabric)
//!   equals the in-process snapshot: same versions, same sample bytes,
//!   same served predictions.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use push::data::{synth, Batch, DataLoader};
use push::device::CostModel;
use push::infer::sgmcmc::{
    linear_native_manifest, linear_native_model, SgMcmc, SgmcmcAlgo, SgmcmcConfig, Schedule,
};
use push::pd::{Topology, TransportKind};
use push::runtime::Tensor;
use push::util::rng::Rng;
use push::{NelConfig, PushDist};

const D: usize = 6;
const BATCH: usize = 8;
const CAP: usize = 8;

fn pd_with(nodes: usize, transport: TransportKind) -> PushDist {
    let cfg = NelConfig {
        num_devices: 2,
        cache_size: 4,
        cost: CostModel::free(),
        control_workers: 4,
        seed: 7,
        ..NelConfig::default()
    };
    PushDist::with_topology(
        &linear_native_manifest(D, BATCH),
        "linear_native",
        cfg,
        &Topology { nodes, transport },
    )
    .unwrap()
}

fn init_params(i: usize) -> Tensor {
    Tensor::f32(vec![D], Rng::new(0xD1CE).fold_in(i as u64).normal_vec(D))
}

fn chain_cfg(particles: usize, algo: SgmcmcAlgo, temperature: f32) -> SgmcmcConfig {
    SgmcmcConfig {
        particles,
        algo,
        schedule: Schedule::Constant { eps: 2e-2 },
        temperature,
        friction: 0.2,
        // no burn-in, thin 1: reservoirs fill from step 0, and 30 steps
        // against CAP = 8 drive Algorithm R's replacement path too
        burn_in: 0,
        thin: 1,
        max_samples: CAP,
        prior_std: None,
        seed: 33,
        model: linear_native_model(),
        init: Some(Arc::new(init_params)),
    }
}

fn fixed_batches(n_batches: usize, seed: u64) -> Vec<Batch> {
    let data = synth::linear(BATCH * n_batches, D, 0.05, seed);
    DataLoader::new(data, BATCH, false, 0).epoch()
}

fn probe_x() -> Tensor {
    Tensor::f32(vec![BATCH, D], Rng::new(0x9a0b).normal_vec(BATCH * D))
}

/// (a) no panic/deadlock, (b) no torn reservoir versions, (c) training is
/// bit-identical with vs without serve traffic — all in one run pair.
#[test]
fn serving_under_load_never_tears_or_perturbs_training() {
    let particles = 64;
    let batches = fixed_batches(30, 5);
    let x = probe_x();

    // ---- run 1: SGLD training with 8 reader threads hammering ----------
    let cfg = chain_cfg(particles, SgmcmcAlgo::Sgld, 0.0);
    let algo = SgMcmc::new(pd_with(1, TransportKind::InProc), cfg).unwrap();
    let server = Arc::new(algo.serve_handle().unwrap());
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..8)
        .map(|t| {
            let server = server.clone();
            let stop = stop.clone();
            let x = x.clone();
            std::thread::spawn(move || {
                let (mut answered, mut empty) = (0u64, 0u64);
                let mut stamp = t as usize; // distinct stamps per thread
                while !stop.load(Ordering::Relaxed) {
                    let snap = server.refresh(stamp).expect("refresh failed");
                    stamp += 8;
                    for chain in &snap.chains {
                        // the no-torn-snapshot invariant: a version is
                        // COMPLETE — kept set size matches its seen count
                        assert_eq!(
                            chain.samples.len(),
                            chain.seen.min(CAP),
                            "{}: torn reservoir (seen {}, kept {})",
                            chain.pid,
                            chain.seen,
                            chain.samples.len()
                        );
                        for s in &chain.samples {
                            assert_eq!(s.element_count(), D, "{}: torn sample", chain.pid);
                        }
                    }
                    match server.predict_mean(&x) {
                        Ok(pred) => {
                            assert_eq!(pred.shape, vec![BATCH, 1]);
                            assert!(pred.as_f32().iter().all(|v| v.is_finite()));
                            answered += 1;
                        }
                        Err(e) => {
                            assert!(
                                format!("{e:#}").contains("no samples"),
                                "unexpected serve error: {e:#}"
                            );
                            empty += 1;
                        }
                    }
                }
                (answered, empty)
            })
        })
        .collect();

    let mut losses = Vec::with_capacity(batches.len());
    for b in &batches {
        losses.push(algo.step_all(&b.x, &b.y).unwrap());
    }
    stop.store(true, Ordering::Relaxed);
    let mut answered = 0u64;
    for h in readers {
        let (ok, _empty) = h.join().expect("serve reader thread panicked");
        answered += ok;
    }

    // the serving path must actually have answered under load (reservoirs
    // fill from the very first step: burn_in 0, thin 1), and must answer
    // now that training is done
    let snap = server.refresh(usize::MAX - 1).unwrap();
    assert_eq!(snap.chains.len(), particles);
    assert!(snap.total_samples() >= particles, "reservoirs never filled");
    server.predict_mean(&x).expect("post-training predict");
    assert!(answered > 0, "8 hammering readers never got one answer");
    let (refreshes, queries) = server.stats();
    assert!(refreshes > 0 && queries > 0);

    // ---- run 2: identical training, zero serve traffic -----------------
    let cfg = chain_cfg(particles, SgmcmcAlgo::Sgld, 0.0);
    let quiet = SgMcmc::new(pd_with(1, TransportKind::InProc), cfg).unwrap();
    let mut quiet_losses = Vec::with_capacity(batches.len());
    for b in &batches {
        quiet_losses.push(quiet.step_all(&b.x, &b.y).unwrap());
    }

    // (c) bit-identical: per-step losses AND final parameters
    assert_eq!(losses.len(), quiet_losses.len());
    for (i, (a, b)) in losses.iter().zip(&quiet_losses).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "step {i}: loss diverged under serve traffic ({a} vs {b})"
        );
    }
    let served = algo.pd().drain_params().unwrap();
    let untouched = quiet.pd().drain_params().unwrap();
    assert_eq!(served.len(), untouched.len());
    for (pid, want) in &untouched {
        assert_eq!(&served[pid], want, "{pid}: params diverged under serve traffic");
    }
}

/// (d) a snapshot taken through a TCP fabric (loopback node servers on
/// real sockets) equals the in-process snapshot — versions, sample bytes,
/// and served predictions.
#[test]
fn snapshot_over_tcp_matches_in_process() {
    let particles = 6;
    let batches = fixed_batches(8, 9);
    let x = probe_x();

    let run = |pd: PushDist| -> SgMcmc {
        let algo = SgMcmc::new(pd, chain_cfg(particles, SgmcmcAlgo::Sghmc, 1e-3)).unwrap();
        for b in &batches {
            algo.step_all(&b.x, &b.y).unwrap();
        }
        algo
    };
    let local = run(pd_with(1, TransportKind::InProc));
    let tcp = run(pd_with(2, TransportKind::TcpLoopback));

    let s_local = local.serve_handle().unwrap();
    let s_tcp = tcp.serve_handle().unwrap();
    let snap_local = s_local.refresh(1).unwrap();
    let snap_tcp = s_tcp.refresh(1).unwrap();

    assert_eq!(snap_local.versions(), snap_tcp.versions(), "versions diverged over TCP");
    assert!(snap_local.total_samples() > 0);
    for (a, b) in snap_local.chains.iter().zip(&snap_tcp.chains) {
        assert_eq!(a.pid, b.pid);
        assert_eq!(a.samples.len(), b.samples.len(), "{}: kept-set size", a.pid);
        for (sa, sb) in a.samples.iter().zip(&b.samples) {
            // owned wire decode vs zero-copy clone: same bytes exactly
            assert_eq!(sa, sb, "{}: sample bytes diverged over the wire", a.pid);
        }
    }

    // served answers are the same function of the same snapshot
    let pa = s_local.predict_mean(&x).unwrap();
    let pb = s_tcp.predict_mean(&x).unwrap();
    assert_eq!(pa, pb, "served prediction diverged over TCP");
    let va = s_local.predictive_std(&x).unwrap();
    let vb = s_tcp.predictive_std(&x).unwrap();
    assert_eq!(va, vb, "served predictive std diverged over TCP");

    // tcp fabric actually framed the snapshot requests
    let counters = tcp.pd().transport_counters();
    assert!(
        counters.iter().any(|c| c.frames_sent > 0),
        "tcp snapshot produced no frames"
    );
}

/// The epoch-stamped refresh policy: refresh_at is a no-op on the current
/// stamp (same Arc back), a real refresh on a new stamp, and versions
/// only grow.
#[test]
fn refresh_at_caches_by_epoch_stamp_and_versions_grow() {
    let particles = 4;
    let batches = fixed_batches(6, 11);
    let cfg = chain_cfg(particles, SgmcmcAlgo::Sgld, 0.0);
    let algo = SgMcmc::new(pd_with(1, TransportKind::InProc), cfg).unwrap();
    let server = algo.serve_handle().unwrap();

    // before any refresh: the empty snapshot answers nothing
    let err = server.predict_mean(&probe_x()).unwrap_err();
    assert!(format!("{err:#}").contains("no samples"), "{err:#}");

    for b in &batches[..3] {
        algo.step_all(&b.x, &b.y).unwrap();
    }
    let first = server.refresh_at(1).unwrap();
    let cached = server.refresh_at(1).unwrap();
    assert!(Arc::ptr_eq(&first, &cached), "same stamp must reuse the snapshot");

    for b in &batches[3..] {
        algo.step_all(&b.x, &b.y).unwrap();
    }
    let second = server.refresh_at(2).unwrap();
    assert!(!Arc::ptr_eq(&first, &second), "new stamp must re-snapshot");
    for (a, b) in first.versions().iter().zip(second.versions()) {
        assert_eq!(a.0, b.0);
        assert!(a.1 <= b.1, "{}: version went backwards ({} -> {})", a.0, a.1, b.1);
    }
    assert_eq!(second.versions().iter().map(|v| v.1).max(), Some(6), "6 candidates seen");

    // the never-refreshed sentinel stamp must SNAPSHOT, not hand back the
    // empty initial snapshot as a cache hit
    let sentinel = server.refresh_at(usize::MAX).unwrap();
    assert_eq!(sentinel.chains.len(), particles);
    assert!(sentinel.total_samples() > 0, "sentinel stamp returned the empty snapshot");
}
