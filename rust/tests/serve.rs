//! Serving-under-load stress tests (hermetic: native linear model, no
//! artifacts, no PJRT; the TCP case uses real 127.0.0.1 sockets).
//!
//! The acceptance bar of the pipelined-data + serving subsystem
//! (DESIGN.md §10):
//! * hammering `PosteriorServer::predict_mean` from 8 threads while SGLD
//!   trains 64 particles on the M:N scheduler neither panics nor
//!   deadlocks;
//! * every snapshot a reader takes is a COMPLETE reservoir version —
//!   `samples.len() == min(seen, cap)` for every chain, never a torn
//!   mid-commit mix (the chain handler commits `(samples, seen)`
//!   atomically);
//! * training under full serve traffic produces BIT-IDENTICAL losses and
//!   final parameters to a run with zero traffic — serving reads
//!   snapshots, it never perturbs chains;
//! * a snapshot taken over TCP (`spawn_loopback_node`-backed fabric)
//!   equals the in-process snapshot: same versions, same sample bytes,
//!   same served predictions.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use push::data::{synth, Batch, DataLoader};
use push::device::CostModel;
use push::infer::sgmcmc::{
    linear_native_manifest, linear_native_model, SgMcmc, SgmcmcAlgo, SgmcmcConfig, Schedule,
};
use push::infer::{Overloaded, ServeConfig};
use push::pd::transport::{wait_deadline, NodeTransport, TcpNode};
use push::pd::{Topology, TransportKind};
use push::runtime::Tensor;
use push::util::rng::Rng;
use push::{NelConfig, Pid, PushDist};

const D: usize = 6;
const BATCH: usize = 8;
const CAP: usize = 8;

fn pd_with(nodes: usize, transport: TransportKind) -> PushDist {
    let cfg = NelConfig {
        num_devices: 2,
        cache_size: 4,
        cost: CostModel::free(),
        control_workers: 4,
        seed: 7,
        ..NelConfig::default()
    };
    PushDist::with_topology(
        &linear_native_manifest(D, BATCH),
        "linear_native",
        cfg,
        &Topology { nodes, transport },
    )
    .unwrap()
}

fn init_params(i: usize) -> Tensor {
    Tensor::f32(vec![D], Rng::new(0xD1CE).fold_in(i as u64).normal_vec(D))
}

fn chain_cfg(particles: usize, algo: SgmcmcAlgo, temperature: f32) -> SgmcmcConfig {
    SgmcmcConfig {
        particles,
        algo,
        schedule: Schedule::Constant { eps: 2e-2 },
        temperature,
        friction: 0.2,
        // no burn-in, thin 1: reservoirs fill from step 0, and 30 steps
        // against CAP = 8 drive Algorithm R's replacement path too
        burn_in: 0,
        thin: 1,
        max_samples: CAP,
        prior_std: None,
        seed: 33,
        model: linear_native_model(),
        init: Some(Arc::new(init_params)),
    }
}

fn fixed_batches(n_batches: usize, seed: u64) -> Vec<Batch> {
    let data = synth::linear(BATCH * n_batches, D, 0.05, seed);
    DataLoader::new(data, BATCH, false, 0).epoch()
}

fn probe_x() -> Tensor {
    Tensor::f32(vec![BATCH, D], Rng::new(0x9a0b).normal_vec(BATCH * D))
}

/// (a) no panic/deadlock, (b) no torn reservoir versions, (c) training is
/// bit-identical with vs without serve traffic — all in one run pair.
#[test]
fn serving_under_load_never_tears_or_perturbs_training() {
    let particles = 64;
    let batches = fixed_batches(30, 5);
    let x = probe_x();

    // ---- run 1: SGLD training with 8 reader threads hammering ----------
    let cfg = chain_cfg(particles, SgmcmcAlgo::Sgld, 0.0);
    let algo = SgMcmc::new(pd_with(1, TransportKind::InProc), cfg).unwrap();
    let server = Arc::new(algo.serve_handle().unwrap());
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..8)
        .map(|t| {
            let server = server.clone();
            let stop = stop.clone();
            let x = x.clone();
            std::thread::spawn(move || {
                let (mut answered, mut empty) = (0u64, 0u64);
                let mut stamp = t as usize; // distinct stamps per thread
                while !stop.load(Ordering::Relaxed) {
                    let snap = server.refresh(stamp).expect("refresh failed");
                    stamp += 8;
                    for chain in &snap.chains {
                        // the no-torn-snapshot invariant: a version is
                        // COMPLETE — kept set size matches its seen count
                        assert_eq!(
                            chain.samples.len(),
                            chain.seen.min(CAP),
                            "{}: torn reservoir (seen {}, kept {})",
                            chain.pid,
                            chain.seen,
                            chain.samples.len()
                        );
                        for s in &chain.samples {
                            assert_eq!(s.element_count(), D, "{}: torn sample", chain.pid);
                        }
                    }
                    match server.predict_mean(&x) {
                        Ok(pred) => {
                            assert_eq!(pred.shape, vec![BATCH, 1]);
                            assert!(pred.as_f32().iter().all(|v| v.is_finite()));
                            answered += 1;
                        }
                        Err(e) => {
                            assert!(
                                format!("{e:#}").contains("no samples"),
                                "unexpected serve error: {e:#}"
                            );
                            empty += 1;
                        }
                    }
                }
                (answered, empty)
            })
        })
        .collect();

    let mut losses = Vec::with_capacity(batches.len());
    for b in &batches {
        losses.push(algo.step_all(&b.x, &b.y).unwrap());
    }
    stop.store(true, Ordering::Relaxed);
    let mut answered = 0u64;
    for h in readers {
        let (ok, _empty) = h.join().expect("serve reader thread panicked");
        answered += ok;
    }

    // the serving path must actually have answered under load (reservoirs
    // fill from the very first step: burn_in 0, thin 1), and must answer
    // now that training is done
    let snap = server.refresh(usize::MAX - 1).unwrap();
    assert_eq!(snap.chains.len(), particles);
    assert!(snap.total_samples() >= particles, "reservoirs never filled");
    server.predict_mean(&x).expect("post-training predict");
    assert!(answered > 0, "8 hammering readers never got one answer");
    let (refreshes, queries) = server.stats();
    assert!(refreshes > 0 && queries > 0);

    // ---- run 2: identical training, zero serve traffic -----------------
    let cfg = chain_cfg(particles, SgmcmcAlgo::Sgld, 0.0);
    let quiet = SgMcmc::new(pd_with(1, TransportKind::InProc), cfg).unwrap();
    let mut quiet_losses = Vec::with_capacity(batches.len());
    for b in &batches {
        quiet_losses.push(quiet.step_all(&b.x, &b.y).unwrap());
    }

    // (c) bit-identical: per-step losses AND final parameters
    assert_eq!(losses.len(), quiet_losses.len());
    for (i, (a, b)) in losses.iter().zip(&quiet_losses).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "step {i}: loss diverged under serve traffic ({a} vs {b})"
        );
    }
    let served = algo.pd().drain_params().unwrap();
    let untouched = quiet.pd().drain_params().unwrap();
    assert_eq!(served.len(), untouched.len());
    for (pid, want) in &untouched {
        assert_eq!(&served[pid], want, "{pid}: params diverged under serve traffic");
    }
}

/// (d) a snapshot taken through a TCP fabric (loopback node servers on
/// real sockets) equals the in-process snapshot — versions, sample bytes,
/// and served predictions.
#[test]
fn snapshot_over_tcp_matches_in_process() {
    let particles = 6;
    let batches = fixed_batches(8, 9);
    let x = probe_x();

    let run = |pd: PushDist| -> SgMcmc {
        let algo = SgMcmc::new(pd, chain_cfg(particles, SgmcmcAlgo::Sghmc, 1e-3)).unwrap();
        for b in &batches {
            algo.step_all(&b.x, &b.y).unwrap();
        }
        algo
    };
    let local = run(pd_with(1, TransportKind::InProc));
    let tcp = run(pd_with(2, TransportKind::TcpLoopback));

    let s_local = local.serve_handle().unwrap();
    let s_tcp = tcp.serve_handle().unwrap();
    let snap_local = s_local.refresh(1).unwrap();
    let snap_tcp = s_tcp.refresh(1).unwrap();

    assert_eq!(snap_local.versions(), snap_tcp.versions(), "versions diverged over TCP");
    assert!(snap_local.total_samples() > 0);
    for (a, b) in snap_local.chains.iter().zip(&snap_tcp.chains) {
        assert_eq!(a.pid, b.pid);
        assert_eq!(a.samples.len(), b.samples.len(), "{}: kept-set size", a.pid);
        for (sa, sb) in a.samples.iter().zip(&b.samples) {
            // owned wire decode vs zero-copy clone: same bytes exactly
            assert_eq!(sa, sb, "{}: sample bytes diverged over the wire", a.pid);
        }
    }

    // served answers are the same function of the same snapshot
    let pa = s_local.predict_mean(&x).unwrap();
    let pb = s_tcp.predict_mean(&x).unwrap();
    assert_eq!(pa, pb, "served prediction diverged over TCP");
    let va = s_local.predictive_std(&x).unwrap();
    let vb = s_tcp.predictive_std(&x).unwrap();
    assert_eq!(va, vb, "served predictive std diverged over TCP");

    // tcp fabric actually framed the snapshot requests
    let counters = tcp.pd().transport_counters();
    assert!(
        counters.iter().any(|c| c.frames_sent > 0),
        "tcp snapshot produced no frames"
    );
}

/// The epoch-stamped refresh policy: refresh_at is a no-op on the current
/// stamp (same Arc back), a real refresh on a new stamp, and versions
/// only grow.
#[test]
fn refresh_at_caches_by_epoch_stamp_and_versions_grow() {
    let particles = 4;
    let batches = fixed_batches(6, 11);
    let cfg = chain_cfg(particles, SgmcmcAlgo::Sgld, 0.0);
    let algo = SgMcmc::new(pd_with(1, TransportKind::InProc), cfg).unwrap();
    let server = algo.serve_handle().unwrap();

    // before any refresh: the empty snapshot answers nothing
    let err = server.predict_mean(&probe_x()).unwrap_err();
    assert!(format!("{err:#}").contains("no samples"), "{err:#}");

    for b in &batches[..3] {
        algo.step_all(&b.x, &b.y).unwrap();
    }
    let first = server.refresh_at(1).unwrap();
    let cached = server.refresh_at(1).unwrap();
    assert!(Arc::ptr_eq(&first, &cached), "same stamp must reuse the snapshot");

    for b in &batches[3..] {
        algo.step_all(&b.x, &b.y).unwrap();
    }
    let second = server.refresh_at(2).unwrap();
    assert!(!Arc::ptr_eq(&first, &second), "new stamp must re-snapshot");
    for (a, b) in first.versions().iter().zip(second.versions()) {
        assert_eq!(a.0, b.0);
        assert!(a.1 <= b.1, "{}: version went backwards ({} -> {})", a.0, a.1, b.1);
    }
    assert_eq!(second.versions().iter().map(|v| v.1).max(), Some(6), "6 candidates seen");

    // the old usize::MAX never-refreshed sentinel is gone: the empty
    // snapshot is simply unstamped (epoch None), so EVERY stamp —
    // usize::MAX included — caches like any other stamp
    let third = server.refresh_at(usize::MAX).unwrap();
    assert_eq!(third.epoch, Some(usize::MAX));
    assert_eq!(third.chains.len(), particles);
    assert!(third.total_samples() > 0);
    let cached = server.refresh_at(usize::MAX).unwrap();
    assert!(Arc::ptr_eq(&third, &cached), "same stamp must reuse the snapshot");
}

/// The batched snapshot protocol's acceptance bar: a refresh is exactly
/// ONE `SnapshotNode` frame per node, regardless of chain count
/// (transport-counter asserted — 16 chains over 2 TCP nodes used to cost
/// 16 `ParticleState` round-trips).
#[test]
fn refresh_is_one_snapshot_frame_per_node() {
    let particles = 16;
    let algo = SgMcmc::new(
        pd_with(2, TransportKind::TcpLoopback),
        chain_cfg(particles, SgmcmcAlgo::Sgld, 0.0),
    )
    .unwrap();
    for b in &fixed_batches(4, 13) {
        algo.step_all(&b.x, &b.y).unwrap();
    }
    let server = algo.serve_handle().unwrap();
    let before: Vec<u64> =
        algo.pd().transport_counters().iter().map(|c| c.frames_sent).collect();
    let snap = server.refresh(1).unwrap();
    assert_eq!(snap.chains.len(), particles);
    assert!(snap.staleness.is_complete());
    assert!(snap.total_samples() > 0);
    let after: Vec<u64> =
        algo.pd().transport_counters().iter().map(|c| c.frames_sent).collect();
    for (n, (b, a)) in before.iter().zip(&after).enumerate() {
        assert_eq!(
            a - b,
            1,
            "node {n}: a refresh must cost exactly ONE SnapshotNode frame, saw {}",
            a - b
        );
    }
}

/// A refresh deadline binds the wait itself, not the heartbeat monitor's
/// `dead_after`: against a peer that accepts but never answers (the
/// silent-death shape), the batched snapshot's futures fail within ~2x
/// the deadline instead of hanging. The deadline budget is SHARED — the
/// first wait consumes it and every later future fails immediately.
#[test]
fn snapshot_deadline_expires_against_mute_peer() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let node = TcpNode::connect(addr).unwrap();
    let deadline = Duration::from_millis(150);

    let futs = node.snapshot_node(&[Pid(0), Pid(1), Pid(2)]);
    assert_eq!(futs.len(), 3);
    let t0 = Instant::now();
    let expiry = Some(Instant::now() + deadline);
    for fut in &futs {
        let err = wait_deadline(fut, expiry, Some(deadline)).unwrap_err();
        assert!(err.msg.contains("deadline"), "not a deadline failure: {}", err.msg);
        // the CONFIGURED budget is named, not just the residual wait —
        // later futures in a shared-expiry batch have ~0 residual and the
        // old message ("expired after 0ns") read as a config of zero
        assert!(err.msg.contains("150ms"), "configured budget not named: {}", err.msg);
        assert!(err.msg.contains("residual"), "residual wait not named: {}", err.msg);
    }
    let waited = t0.elapsed();
    assert!(waited >= Duration::from_millis(100), "deadline cut short: {waited:?}");
    assert!(waited < deadline * 2, "deadline {deadline:?} but waited {waited:?}");
    drop(listener);
}

/// Two servers over the SAME chains must retry on DISTINCT jittered
/// schedules: the backoff RNG is keyed by a per-server nonce, not a
/// process-wide constant. A constant seed once made every server in a
/// fleet sleep the identical "jittered" duration and hammer a recovering
/// node in lockstep — exactly the herd the jitter exists to break.
#[test]
fn two_servers_retry_on_distinct_jitter_schedules() {
    let algo = SgMcmc::new(
        pd_with(1, TransportKind::InProc),
        chain_cfg(4, SgmcmcAlgo::Sgld, 0.0),
    )
    .unwrap();
    let a = algo.serve_handle().unwrap();
    let b = algo.serve_handle().unwrap();

    let sched_a: Vec<Duration> = (1..=4).map(|n| a.retry_backoff(n)).collect();
    let sched_b: Vec<Duration> = (1..=4).map(|n| b.retry_backoff(n)).collect();
    // deterministic per server: auditing a schedule doesn't change it
    assert_eq!(sched_a, (1..=4).map(|n| a.retry_backoff(n)).collect::<Vec<_>>());
    assert_eq!(sched_b, (1..=4).map(|n| b.retry_backoff(n)).collect::<Vec<_>>());
    // ...but distinct between servers
    assert_ne!(sched_a, sched_b, "two servers retry in lockstep: {sched_a:?}");

    // every sleep stays inside the ±25% envelope of 2^(n-1) * backoff
    let base = ServeConfig::default().refresh_backoff.as_millis() as u64;
    for sched in [&sched_a, &sched_b] {
        for (i, d) in sched.iter().enumerate() {
            let base_ms = base << i;
            let lo = Duration::from_millis(base_ms - base_ms / 4);
            let hi = Duration::from_millis(base_ms - base_ms / 4 + base_ms / 2);
            assert!(
                *d >= lo && *d <= hi,
                "attempt {}: {d:?} outside the jitter envelope [{lo:?}, {hi:?}]",
                i + 1
            );
        }
    }
}

/// Admission control: with a 1-slot gate, concurrent hammering sheds with
/// the typed [`Overloaded`] error — and shedding never corrupts: every
/// ADMITTED answer is bit-identical to an unloaded server reading the
/// same snapshot.
#[test]
fn admission_gate_sheds_with_typed_overloaded() {
    let particles = 16;
    let algo = SgMcmc::new(
        pd_with(1, TransportKind::InProc),
        chain_cfg(particles, SgmcmcAlgo::Sgld, 0.0),
    )
    .unwrap();
    for b in &fixed_batches(6, 17) {
        algo.step_all(&b.x, &b.y).unwrap();
    }
    let limited = Arc::new(
        algo.serve_handle_with(ServeConfig { max_inflight: 1, ..ServeConfig::default() })
            .unwrap(),
    );
    let unloaded = algo.serve_handle().unwrap();
    limited.refresh(1).unwrap();
    unloaded.refresh(1).unwrap();
    let x = probe_x();
    let want = unloaded.predict_mean(&x).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let threads: Vec<_> = (0..4)
        .map(|_| {
            let srv = limited.clone();
            let stop = stop.clone();
            let x = x.clone();
            let want = want.clone();
            std::thread::spawn(move || {
                let mut sheds = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    match srv.predict_mean(&x) {
                        Ok(pred) => {
                            assert_eq!(pred, want, "admitted answer diverged under shedding")
                        }
                        Err(e) => {
                            let o = e
                                .downcast_ref::<Overloaded>()
                                .unwrap_or_else(|| panic!("non-overload serve error: {e:#}"));
                            assert_eq!(o.limit, 1);
                            sheds += 1;
                        }
                    }
                }
                sheds
            })
        })
        .collect();
    // run until shedding has provably happened (4 threads on a 1-slot
    // gate collide almost immediately; the bound is for slow machines)
    let t0 = Instant::now();
    while limited.serve_stats().shed == 0 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::yield_now();
    }
    stop.store(true, Ordering::Relaxed);
    let shed_seen: u64 =
        threads.into_iter().map(|h| h.join().expect("query thread panicked")).sum();
    let st = limited.serve_stats();
    assert!(st.shed > 0 && shed_seen > 0, "4 threads on a 1-slot gate never shed");
    assert_eq!(st.shed, shed_seen, "server shed count != typed Overloaded errors seen");
    assert!(st.served > 0, "a 1-slot gate must still admit");
    assert_eq!(st.queries, st.served, "admitted queries all had samples to answer from");
    assert!(st.latency.count() > 0, "admitted queries must be histogrammed");
    assert_eq!(st.stale_served, 0, "complete snapshot served as stale");
}

/// Deterministic fault-plan cases (the transport's fault hooks are only
/// compiled under `--features faultinject` for integration tests).
#[cfg(feature = "faultinject")]
mod faults {
    use super::*;
    use push::pd::checkpoint::Checkpoint;
    use push::pd::transport::fault::{self, FaultPlan};

    /// The degrade-to-stale story end to end: killing a node mid-serving
    /// degrades the snapshot to the surviving chains (correct missing-pid
    /// record, versions never go backwards, queries still answer and SAY
    /// they are stale), and a refresh after `recover` migrates the dead
    /// node's chains home and heals back to a complete snapshot.
    #[test]
    fn refresh_degrades_to_stale_then_heals_after_recovery() {
        let particles = 8;
        let batches = fixed_batches(6, 19);
        let algo = SgMcmc::new(
            pd_with(2, TransportKind::TcpLoopback),
            chain_cfg(particles, SgmcmcAlgo::Sgld, 0.0),
        )
        .unwrap()
        .with_recovery(1);
        let mut ckpt = Checkpoint::capture(algo.pd()).unwrap();
        let mut used = 0usize;
        for b in &batches[..4] {
            algo.step_all_recovering(&b.x, &b.y, &mut ckpt, &mut used).unwrap();
        }
        let server = algo
            .serve_handle_with(ServeConfig {
                refresh_retries: 1,
                refresh_backoff: Duration::from_millis(5),
                ..ServeConfig::default()
            })
            .unwrap();
        let x = probe_x();
        let full = server.refresh(1).unwrap();
        assert!(full.staleness.is_complete());
        assert_eq!(full.chains.len(), particles);

        // sever node 1's link on its next data frame: the refresh's own
        // SnapshotNode frame is the frame that dies
        let addr = algo.pd().peer_addr(1).expect("node 1 is a wire link");
        fault::set_plan(addr, FaultPlan { drop_after_frames: Some(0), ..FaultPlan::default() });
        let degraded = server.refresh(2).unwrap();
        fault::clear(addr);

        let lost: Vec<Pid> = full
            .chains
            .iter()
            .map(|c| c.pid)
            .filter(|p| algo.pd().node_of(*p) == Some(1))
            .collect();
        assert!(!lost.is_empty(), "round-robin placement put nothing on node 1?");
        assert_eq!(degraded.staleness.missing, lost, "wrong missing-pid record");
        assert_eq!(degraded.epoch, Some(2), "degraded refresh must still stamp");
        // carried forward from the last good snapshot: every chain still
        // present, versions never below the full snapshot's
        assert_eq!(degraded.chains.len(), particles);
        for (a, b) in full.versions().iter().zip(degraded.versions()) {
            assert_eq!(a.0, b.0);
            assert!(b.1 >= a.1, "{}: version went backwards ({} -> {})", a.0, a.1, b.1);
        }
        // the lost chains answer with exactly their pre-failure reservoirs
        for (a, b) in full.chains.iter().zip(&degraded.chains) {
            if lost.contains(&a.pid) {
                assert_eq!(a.seen, b.seen, "{}: carried version changed", a.pid);
                assert_eq!(a.samples, b.samples, "{}: carried samples changed", a.pid);
            }
        }
        // queries still answer, and the result says it is stale
        let res = server.query_mean(&x).unwrap();
        assert_eq!(res.staleness.missing, lost);
        assert_eq!(res.epoch, Some(2));
        assert!(res.value.as_f32().iter().all(|v| v.is_finite()));

        // recover: the next training step detects the dead node and
        // migrates its chains onto node 0 (bit-identical replay, PR6)
        for b in &batches[4..] {
            algo.step_all_recovering(&b.x, &b.y, &mut ckpt, &mut used).unwrap();
        }
        assert_eq!(used, 1, "exactly one recovery round");
        for pid in &lost {
            assert_eq!(algo.pd().node_of(*pid), Some(0), "{pid} not migrated");
        }
        // a post-migration refresh heals back to a COMPLETE snapshot
        let healed = server.refresh(3).unwrap();
        assert!(healed.staleness.is_complete(), "post-recover refresh still degraded");
        assert_eq!(healed.staleness.epoch_lag, 0);
        assert_eq!(healed.chains.len(), particles);
        for (a, b) in degraded.versions().iter().zip(healed.versions()) {
            assert!(b.1 >= a.1, "{}: version went backwards across recovery", a.0);
        }
        server.predict_mean(&x).expect("healed snapshot must answer");

        let st = server.serve_stats();
        assert_eq!(st.refreshes, 3);
        assert_eq!(st.degraded_refreshes, 1);
        assert!(st.stale_served >= 1, "the stale answer was not counted");
    }
}
