//! SciML uncertainty quantification (the paper's §5.1 motivation:
//! "scientists and engineers want to provide guarantees on the
//! trustworthiness of surrogate models").
//!
//! Trains a deep ensemble of UNet surrogates on the 1-D advection
//! operator-learning task, then uses the particle spread as a predictive
//! uncertainty estimate and checks it correlates with the true error —
//! the basic UQ sanity test for BDL surrogates.
//!
//! ```sh
//! make artifacts && cargo run --release --example sciml_uq
//! ```

use anyhow::Result;
use push::bench::{data_for, lr_for};
use push::data::DataLoader;
use push::device::CostModel;
use push::infer::{DeepEnsemble, Infer};
use push::runtime::{artifacts_dir, Manifest, Tensor};
use push::util::flags::Flags;
use push::{NelConfig, PushDist};

fn main() -> Result<()> {
    let flags = Flags::from_env().map_err(anyhow::Error::msg)?;
    let particles = flags.usize_or("particles", 6).map_err(anyhow::Error::msg)?;
    let epochs = flags.usize_or("epochs", 20).map_err(anyhow::Error::msg)?;

    let manifest = Manifest::load(artifacts_dir())?;
    let pd = PushDist::new(
        &manifest,
        "unet_fig4",
        NelConfig {
            num_devices: 2,
            cache_size: 4,
            cost: CostModel::default(),
            seed: 7,
            ..NelConfig::default()
        },
    )?;
    let model = pd.model().clone();
    let lr = lr_for(&model);
    println!(
        "UQ: UNet-1D advection surrogate, {} params x {particles} particles",
        model.param_count
    );

    let n_train = model.batch() * 8;
    let n_test = model.batch();
    let all = data_for(&model, n_train + n_test, 3)?;
    let (train, test) = all.split(n_test as f32 / (n_train + n_test) as f32);
    let mut loader = DataLoader::new(train, model.batch(), true, 11).with_max_batches(8);

    let mut ens = DeepEnsemble::new(pd, particles, lr)?;
    println!("\nepoch  mean_loss");
    for e in 0..epochs {
        let rep = ens.train(&mut loader, 1)?;
        if e % 4 == 0 || e == epochs - 1 {
            println!("{e:>5}  {:.5}", rep.final_loss());
        }
    }

    // ---- predictive mean + spread on the held-out batch ----
    let batch = test.gather(&(0..model.batch()).collect::<Vec<_>>());
    let pids = ens.pids();
    let preds: Vec<Tensor> = pids
        .iter()
        .map(|p| ens.pd().forward(*p, batch.x.clone()).wait().unwrap().tensor().unwrap())
        .collect();
    let n = preds.len() as f32;
    let len = preds[0].element_count();
    let mut mean = vec![0.0f32; len];
    for p in &preds {
        for (m, v) in mean.iter_mut().zip(p.as_f32()) {
            *m += v / n;
        }
    }
    let mut var = vec![0.0f32; len];
    for p in &preds {
        for ((va, v), m) in var.iter_mut().zip(p.as_f32()).zip(&mean) {
            *va += (v - m) * (v - m) / n;
        }
    }
    let y = batch.y.as_f32();
    let err: Vec<f32> = mean.iter().zip(y).map(|(m, t)| (m - t).abs()).collect();
    let std: Vec<f32> = var.iter().map(|v| v.sqrt()).collect();

    // rank correlation (Spearman-ish via Pearson on ranks would be heavy;
    // Pearson on |err| vs std is the standard quick UQ diagnostic)
    let pearson = {
        let n = err.len() as f64;
        let (me, ms) = (
            err.iter().map(|v| *v as f64).sum::<f64>() / n,
            std.iter().map(|v| *v as f64).sum::<f64>() / n,
        );
        let mut num = 0.0;
        let mut de = 0.0;
        let mut ds = 0.0;
        for (e, s) in err.iter().zip(&std) {
            let a = *e as f64 - me;
            let b = *s as f64 - ms;
            num += a * b;
            de += a * a;
            ds += b * b;
        }
        num / (de.sqrt() * ds.sqrt() + 1e-12)
    };

    let mse: f64 =
        mean.iter().zip(y).map(|(m, t)| ((m - t) as f64).powi(2)).sum::<f64>() / len as f64;
    println!("\n== UQ results on held-out advection fields ==");
    println!("ensemble-mean MSE         : {mse:.5}");
    println!("mean predictive std       : {:.5}", std.iter().sum::<f32>() / len as f32);
    println!("corr(|error|, pred. std)  : {pearson:.3}  (positive = informative uncertainty)");
    println!("\nper-point sample (x=grid index of field 0):");
    println!("  idx   truth    mean     std     |err|");
    for i in (0..model.x_shape[1]).step_by(8) {
        println!(
            "{:>5}  {:>6.3}  {:>6.3}  {:>6.4}  {:>6.4}",
            i, y[i], mean[i], std[i], err[i]
        );
    }
    Ok(())
}
