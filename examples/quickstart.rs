//! Quickstart: the paper's Figures 1 + 2 in Rust.
//!
//! Builds a Push distribution over the small MLP, registers the all-to-all
//! `_gather` handler (Figure 1), launches it from particle 0, then runs a
//! few synchronized training steps and prints the posterior-mean
//! prediction. Run with:
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::{anyhow, Result};
use push::data::{synth, DataLoader};
use push::device::CostModel;
use push::infer::{DeepEnsemble, Infer};
use push::nel::CreateOpts;
use push::particle::{handler, PFuture, Value};
use push::runtime::{artifacts_dir, Manifest};
use push::{NelConfig, PushDist};

fn main() -> Result<()> {
    let manifest = Manifest::load(artifacts_dir())?;
    let cfg = NelConfig {
        num_devices: 2,
        cache_size: 4,
        cost: CostModel::default(),
        trace: true, // record the Figure-3b event timeline
        seed: 42,
        ..NelConfig::default()
    };

    // push_dist = Push(nn, *args)  (paper Figure 2, line 2)
    let pd = PushDist::new(&manifest, "mlp_small", cfg)?;
    println!(
        "PD over {} ({} params, task={}) on {} simulated devices",
        pd.model().name,
        pd.model().param_count,
        pd.model().task,
        pd.nel().num_devices()
    );

    // _gather: the paper's Figure 1, line for line.
    let gather = handler(|particle, _args| {
        // 1. Determine other particles
        let other_particles = particle.other_particles();
        // 2. Gather every other particle's parameters
        let futures: Vec<PFuture> = other_particles.iter().map(|pid| particle.get(*pid)).collect();
        // 3. Wait for the results
        let views = PFuture::wait_all(&futures)?;
        // 4. View a particle's parameters (read-only copy)
        let first = views[0].as_tensor()?;
        println!(
            "  [gather on {}] got {} views; first starts with {:?}",
            particle.pid,
            views.len(),
            &first.as_f32()[..4]
        );
        Ok(Value::Usize(views.len()))
    });

    // p_create x4, each answering "GATHER" (paper Figure 2, lines 4-6)
    let pids = pd.p_create_n(4, |_| CreateOpts {
        receive: [("GATHER".to_string(), gather.clone())].into_iter().collect(),
        ..CreateOpts::default()
    })?;
    println!("created particles: {pids:?}");

    // p_launch + p_wait (paper Figure 2, line 7)
    let fut = pd.p_launch(pids[0], "GATHER", vec![]);
    let got = pd.p_wait(&[fut]).map_err(|e| anyhow!("{e}"))?;
    println!("all-to-all gather returned {got:?}\n");

    // A few epochs of the simplest BDL algorithm: a deep ensemble.
    let model = pd.model().clone();
    let data = synth::linear(model.batch() * 8, model.x_shape[1], 0.05, 7);
    let mut loader = DataLoader::new(data, model.batch(), true, 1).with_max_batches(8);
    let mut ensemble = DeepEnsemble::new(pd, 4, 5e-3)?;
    let report = ensemble.train(&mut loader, 5)?;
    for (e, ep) in report.epochs.iter().enumerate() {
        println!("epoch {e}: mean loss {:.4} ({:.3}s)", ep.mean_loss, ep.secs);
    }

    let batch = loader.epoch()[0].clone();
    let pred = ensemble.predict_mean(&batch.x)?;
    println!(
        "\nposterior-mean prediction (first 4): {:?}\ntargets                  (first 4): {:?}",
        &pred.as_f32()[..4],
        &batch.y.as_f32()[..4]
    );

    // Figure-3b style event timeline (first 25 events)
    let trace = ensemble.pd().nel().trace().snapshot();
    println!("\nNEL event timeline (first 25 of {} events):", trace.len());
    println!("    t(us)  dev  particle  event          bytes");
    for e in trace.iter().take(25) {
        let pid = e.pid.map(|p| format!("{p}")).unwrap_or_else(|| "-".into());
        println!(
            "{:>9}  {:>3}  {:>8}  {:<13} {:>6}  {}",
            e.t_us,
            e.device,
            pid,
            e.kind.name(),
            e.bytes,
            e.note_str()
        );
    }

    let stats = ensemble.pd().stats();
    println!("\nmessages sent: {} (cross-device {})", stats.msgs_sent, stats.msgs_cross_device);
    for (i, d) in stats.devices.iter().enumerate() {
        println!("{}", d.summary(i));
    }
    Ok(())
}
