//! End-to-end driver (DESIGN.md §5, row "E2E"): train a transformer
//! ensemble with multi-SWAG on the synthetic-MNIST workload for a few
//! hundred steps, logging the loss curve, then evaluate standard vs
//! multi-SWAG accuracy on a held-out split.
//!
//! This proves every layer composes: Rust coordinator -> NEL -> simulated
//! devices -> PJRT -> AOT HLO (L2 JAX model with the L1 Pallas
//! fused-linear kernel lowered inside). The paper-scale 100M+ ViT is a
//! GPU budget; `vit_e2e` (~1.3M params, the largest this CPU testbed
//! trains in minutes) keeps the identical architecture and protocol —
//! recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_train [-- --steps 300]
//! ```

use std::time::Instant;

use anyhow::Result;
use push::bench::{data_for, lr_for};
use push::data::DataLoader;
use push::device::CostModel;
use push::infer::eval::dataset_accuracy;
use push::infer::{DeepEnsemble, Infer, MultiSwag, SwagConfig};
use push::runtime::{artifacts_dir, Manifest};
use push::util::flags::Flags;
use push::{NelConfig, PushDist};

fn main() -> Result<()> {
    let flags = Flags::from_env().map_err(anyhow::Error::msg)?;
    let model_name = flags.str_or("model", "vit_e2e");
    let steps = flags.usize_or("steps", 300).map_err(anyhow::Error::msg)?;
    let particles = flags.usize_or("particles", 4).map_err(anyhow::Error::msg)?;
    let devices = flags.usize_or("devices", 2).map_err(anyhow::Error::msg)?;
    let batches_per_epoch = 10usize;
    // ceil(steps / batches_per_epoch) without usize::div_ceil (MSRV 1.72)
    let epochs = (steps + batches_per_epoch - 1) / batches_per_epoch;
    let pretrain = (epochs * 7) / 10; // the paper's 7:3 pretrain/SWAG split

    let manifest = Manifest::load(artifacts_dir())?;
    let cfg = NelConfig {
        num_devices: devices,
        cache_size: 4,
        cost: CostModel::default(),
        seed: 1234,
        ..NelConfig::default()
    };
    let pd = PushDist::new(&manifest, &model_name, cfg)?;
    let model = pd.model().clone();
    let lr = lr_for(&model);
    println!(
        "e2e: {model_name} ({} params x {particles} particles = {:.1}M effective) \
         on {devices} devices",
        model.param_count,
        (model.param_count * particles) as f64 / 1e6
    );
    println!(
        "     {steps} steps = {epochs} epochs x {batches_per_epoch} batches, batch {}, lr {lr}",
        model.batch()
    );

    // train/test split of the synthetic-MNIST substitute
    let n_train = model.batch() * batches_per_epoch;
    let n_test = model.batch() * 4;
    let all = data_for(&model, n_train + n_test, 99)?;
    let (train, test) = all.split(n_test as f32 / (n_train + n_test) as f32);
    let mut loader =
        DataLoader::new(train.clone(), model.batch(), true, 5).with_max_batches(batches_per_epoch);

    // ---------------- multi-SWAG training with a loss curve ---------------
    let mut algo = MultiSwag::new(
        pd,
        SwagConfig {
            particles,
            lr,
            pretrain_epochs: pretrain,
            n_samples: 5,
            scale: 1e-3,
            adam: true, // the paper's Tables 3/4 protocol
            seed: 0,
        },
    )?;
    let t0 = Instant::now();
    println!("\nstep  epoch  phase     mean_loss   secs/epoch");
    let mut step_count = 0usize;
    for e in 0..epochs {
        let rep = algo.train(&mut loader, 1)?;
        step_count += batches_per_epoch;
        let phase = if e >= pretrain { "swag" } else { "pretrain" };
        println!(
            "{:>4}  {:>5}  {:<8}  {:>9.4}   {:>8.2}s",
            step_count,
            e,
            phase,
            rep.final_loss(),
            rep.mean_epoch_secs()
        );
    }
    let train_secs = t0.elapsed().as_secs_f64();

    // ---------------- evaluation ------------------------------------------
    let ms_acc = dataset_accuracy(&test, model.batch(), |x| algo.predict_swag(x))?;

    // standard-training comparison: one particle, same total step budget
    let pd_std = PushDist::new(
        &manifest,
        &model_name,
        NelConfig {
            num_devices: devices,
            cache_size: 4,
            cost: CostModel::default(),
            seed: 4321,
            ..NelConfig::default()
        },
    )?;
    let mut std_algo = DeepEnsemble::new(pd_std, 1, lr)?;
    let mut loader2 =
        DataLoader::new(train, model.batch(), true, 5).with_max_batches(batches_per_epoch);
    std_algo.train(&mut loader2, epochs)?;
    let std_acc = dataset_accuracy(&test, model.batch(), |x| std_algo.predict_mean(x))?;

    println!("\n== e2e results ==");
    println!(
        "training wall time      : {train_secs:.1}s for {step_count} steps x {particles} particles"
    );
    println!("multi-SWAG test accuracy: {:.2}%  (majority vote, 5 draws/particle)", 100.0 * ms_acc);
    println!("standard test accuracy  : {:.2}%  (single network, same steps)", 100.0 * std_acc);
    let stats = algo.pd().stats();
    println!("\nmessages: {} total, {} cross-device", stats.msgs_sent, stats.msgs_cross_device);
    for (i, d) in stats.devices.iter().enumerate() {
        println!("{}", d.summary(i));
    }
    Ok(())
}
