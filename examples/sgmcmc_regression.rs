//! SGLD vs SGHMC on noisy linear regression — the SGMCMC particle
//! encoding end to end: per-particle chains over the M:N scheduler, a
//! cyclical cSG-MCMC step-size schedule with warm restarts, bounded
//! posterior-sample reservoirs, and posterior-predictive averaging with an
//! epistemic-uncertainty readout.
//!
//! Fully hermetic: the closed-form linear model
//! (`infer::sgmcmc::linear_native_model`) supplies gradients and forwards,
//! so no artifacts and no PJRT are needed:
//!
//! ```sh
//! cargo run --release --example sgmcmc_regression
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;
use push::data::{synth, DataLoader};
use push::device::CostModel;
use push::infer::sgmcmc::linear_native_model;
use push::infer::{eval, Infer, ModelSource, Schedule, SgMcmc, SgmcmcAlgo, SgmcmcConfig};
use push::runtime::{DType, Manifest, ModelSpec, Tensor};
use push::util::flags::Flags;
use push::util::rng::Rng;
use push::{NelConfig, PushDist};

const D: usize = 8;
const BATCH: usize = 16;

/// A manifest for the closed-form linear model: no artifact entries — the
/// native ModelSource supplies grad/forward, so the PD never touches PJRT.
fn native_manifest() -> Manifest {
    let spec = ModelSpec {
        name: "linear_native".to_string(),
        param_count: D,
        task: "regress".to_string(),
        x_shape: vec![BATCH, D],
        y_shape: vec![BATCH, 1],
        y_dtype: DType::F32,
        arch: "mlp".to_string(),
        meta: BTreeMap::new(),
        entries: BTreeMap::new(),
    };
    Manifest {
        dir: std::path::PathBuf::from("."),
        models: [("linear_native".to_string(), spec)].into_iter().collect(),
        svgd: Vec::new(),
    }
}

fn run_chain(
    algo: SgmcmcAlgo,
    particles: usize,
    epochs: usize,
    batches: usize,
) -> Result<(SgMcmc, Vec<f64>)> {
    let manifest = native_manifest();
    let cfg = NelConfig {
        num_devices: 2,
        cache_size: 8,
        cost: CostModel::default(),
        seed: 55,
        ..NelConfig::default()
    };
    let pd = PushDist::new(&manifest, "linear_native", cfg)?;
    let steps = epochs * batches;
    let mut algo = SgMcmc::new(
        pd,
        SgmcmcConfig {
            particles,
            algo,
            // Three cosine cycles with warm restarts; samples are drawn
            // only in the low-step-size half of each cycle (cSG-MCMC).
            schedule: Schedule::Cyclical {
                eps0: 5e-2,
                cycle_len: (steps / 3).max(1),
                sample_frac: 0.5,
            },
            temperature: 1e-3,
            friction: 0.1,
            burn_in: 0, // the cyclical gate handles exploration
            thin: 1,
            max_samples: 64,
            prior_std: Some(10.0),
            seed: 99,
            model: linear_native_model(),
            init: Some(Arc::new(|i| {
                Tensor::f32(vec![D], Rng::new(1234).fold_in(i as u64).normal_vec(D))
            })),
        },
    )?;
    let data = synth::linear(BATCH * batches, D, 0.1, 13);
    let mut loader = DataLoader::new(data, BATCH, true, 17).with_max_batches(batches);
    let mut curve = Vec::with_capacity(epochs);
    for _ in 0..epochs {
        let rep = algo.train(&mut loader, 1)?;
        curve.push(rep.final_loss());
    }
    Ok((algo, curve))
}

fn main() -> Result<()> {
    let flags = Flags::from_env().map_err(anyhow::Error::msg)?;
    let particles = flags.usize_or("particles", 8).map_err(anyhow::Error::msg)?.max(1);
    let epochs = flags.usize_or("epochs", 30).map_err(anyhow::Error::msg)?.max(1);
    let batches = 6usize;

    let (sgld, sgld_curve) = run_chain(SgmcmcAlgo::Sgld, particles, epochs, batches)?;
    let (sghmc, sghmc_curve) = run_chain(SgmcmcAlgo::Sghmc, particles, epochs, batches)?;

    println!("epoch   sgld_loss   sghmc_loss");
    for e in (0..epochs).step_by(4.max(epochs / 6)) {
        println!("{e:>5}   {:>9.4}   {:>10.4}", sgld_curve[e], sghmc_curve[e]);
    }
    println!(
        "{:>5}   {:>9.4}   {:>10.4}",
        epochs - 1,
        sgld_curve[epochs - 1],
        sghmc_curve[epochs - 1]
    );

    // Reservoir accounting: bounded at max_samples regardless of chain
    // length, uniform over the sampling-phase candidates.
    println!("\n== chains ==");
    for (label, algo) in [("sgld", &sgld), ("sghmc", &sghmc)] {
        for pid in algo.pids() {
            let c = algo.chain(pid);
            println!(
                "{label} {pid}: {} steps, {} candidates seen, {} samples kept{}",
                c.step,
                c.seen,
                c.samples.len(),
                if c.momentum.is_some() { ", momentum carried" } else { "" }
            );
        }
    }

    // Posterior-predictive mean vs targets + epistemic uncertainty: every
    // reservoir sample of every chain is a draw from the (approximate)
    // posterior; the spread of their predictions is the uncertainty.
    let data = synth::linear(BATCH * batches, D, 0.1, 13);
    let b = DataLoader::new(data, BATCH, false, 0).epoch()[0].clone();
    let pred = sgld.predict_mean(&b.x)?;
    println!("\nposterior-predictive MSE (sgld): {:.4}", eval::batch_mse(&pred, &b.y));

    let ModelSource::Native { forward, .. } = linear_native_model() else { unreachable!() };
    let mut sample_preds = Vec::new();
    for pid in sgld.pids() {
        for s in sgld.chain(pid).samples {
            sample_preds.push(forward(&s, &b.x).map_err(anyhow::Error::new)?);
        }
    }
    let std = eval::predictive_std(&sample_preds)?;
    let mean_std: f32 =
        std.as_f32().iter().sum::<f32>() / std.element_count() as f32;
    println!(
        "epistemic std over {} posterior samples: {:.4} (per-point mean)",
        sample_preds.len(),
        mean_std
    );
    println!("predictions (first 4): {:?}", &pred.as_f32()[..4]);
    println!("targets     (first 4): {:?}", &b.y.as_f32()[..4]);
    Ok(())
}
