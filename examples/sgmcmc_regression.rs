//! SGLD vs SGHMC on a native model — the SGMCMC particle encoding end to
//! end: per-particle chains over the M:N scheduler, a cyclical cSG-MCMC
//! step-size schedule with warm restarts, bounded posterior-sample
//! reservoirs, and posterior-predictive averaging with an
//! epistemic-uncertainty readout.
//!
//! Fully hermetic: every registered native model (`infer::models`)
//! supplies closed-form gradients and forwards, so no artifacts and no
//! PJRT are needed. `--model` picks the model (default `linear_native`;
//! classify models report vote accuracy instead of MSE):
//!
//! ```sh
//! cargo run --release --example sgmcmc_regression
//! cargo run --release --example sgmcmc_regression -- --model mlp_native
//! ```

use anyhow::{anyhow, Result};
use push::bench::data_for;
use push::data::DataLoader;
use push::device::CostModel;
use push::infer::{
    eval, native_manifest, native_model, Infer, ModelSource, NativeModel, Schedule, SgMcmc,
    SgmcmcAlgo, SgmcmcConfig,
};
use push::util::flags::Flags;
use push::{NelConfig, PushDist};

fn run_chain(
    nm: &NativeModel,
    algo: SgmcmcAlgo,
    particles: usize,
    epochs: usize,
    batches: usize,
) -> Result<(SgMcmc, Vec<f64>)> {
    let manifest = native_manifest();
    let cfg = NelConfig {
        num_devices: 2,
        cache_size: 8,
        cost: CostModel::default(),
        seed: 55,
        ..NelConfig::default()
    };
    let pd = PushDist::new(&manifest, nm.name, cfg)?;
    let spec = pd.model().clone();
    let steps = epochs * batches;
    let mut algo = SgMcmc::new(
        pd,
        SgmcmcConfig {
            particles,
            algo,
            // Three cosine cycles with warm restarts; samples are drawn
            // only in the low-step-size half of each cycle (cSG-MCMC).
            schedule: Schedule::Cyclical {
                eps0: 5e-2,
                cycle_len: (steps / 3).max(1),
                sample_frac: 0.5,
            },
            temperature: 1e-3,
            friction: 0.1,
            burn_in: 0, // the cyclical gate handles exploration
            thin: 1,
            max_samples: 64,
            prior_std: Some(10.0),
            seed: 99,
            model: nm.source.clone(),
            init: Some(nm.seeded_init(1234)),
        },
    )?;
    let data = data_for(&spec, spec.batch() * batches, 13)?;
    let mut loader = DataLoader::new(data, spec.batch(), true, 17).with_max_batches(batches);
    let mut curve = Vec::with_capacity(epochs);
    for _ in 0..epochs {
        let rep = algo.train(&mut loader, 1)?;
        curve.push(rep.final_loss());
    }
    Ok((algo, curve))
}

fn main() -> Result<()> {
    let flags = Flags::from_env().map_err(anyhow::Error::msg)?;
    let model_name = flags.str_or("model", "linear_native");
    let nm = native_model(&model_name).ok_or_else(|| {
        anyhow!("--model must be a registered native model (linear_native|mlp_native|...)")
    })?;
    let particles = flags.usize_or("particles", 8).map_err(anyhow::Error::msg)?.max(1);
    let epochs = flags.usize_or("epochs", 30).map_err(anyhow::Error::msg)?.max(1);
    let batches = 6usize;

    let (sgld, sgld_curve) = run_chain(&nm, SgmcmcAlgo::Sgld, particles, epochs, batches)?;
    let (sghmc, sghmc_curve) = run_chain(&nm, SgmcmcAlgo::Sghmc, particles, epochs, batches)?;

    println!("epoch   sgld_loss   sghmc_loss");
    for e in (0..epochs).step_by(4.max(epochs / 6)) {
        println!("{e:>5}   {:>9.4}   {:>10.4}", sgld_curve[e], sghmc_curve[e]);
    }
    println!(
        "{:>5}   {:>9.4}   {:>10.4}",
        epochs - 1,
        sgld_curve[epochs - 1],
        sghmc_curve[epochs - 1]
    );

    // Reservoir accounting: bounded at max_samples regardless of chain
    // length, uniform over the sampling-phase candidates.
    println!("\n== chains ==");
    for (label, algo) in [("sgld", &sgld), ("sghmc", &sghmc)] {
        for pid in algo.pids() {
            let c = algo.chain(pid);
            println!(
                "{label} {pid}: {} steps, {} candidates seen, {} samples kept{}",
                c.step,
                c.seen,
                c.samples.len(),
                if c.momentum.is_some() { ", momentum carried" } else { "" }
            );
        }
    }

    // Posterior-predictive mean vs targets + epistemic uncertainty: every
    // reservoir sample of every chain is a draw from the (approximate)
    // posterior; the spread of their predictions is the uncertainty.
    let spec = native_manifest().model(&model_name)?.clone();
    let classify = spec.task == "classify";
    let data = data_for(&spec, spec.batch() * batches, 13)?;
    let b = DataLoader::new(data, spec.batch(), false, 0).epoch()[0].clone();
    let pred = sgld.predict_mean(&b.x)?;
    if classify {
        println!(
            "\nposterior-predictive accuracy (sgld): {:.1}%",
            100.0 * eval::batch_accuracy(&pred, &b.y)
        );
    } else {
        println!("\nposterior-predictive MSE (sgld): {:.4}", eval::batch_mse(&pred, &b.y));
    }

    let ModelSource::Native { forward, .. } = nm.source.clone() else { unreachable!() };
    let mut sample_preds = Vec::new();
    for pid in sgld.pids() {
        for s in sgld.chain(pid).samples {
            sample_preds.push(forward(&s, &b.x).map_err(anyhow::Error::new)?);
        }
    }
    if classify {
        // class votes have no per-point spread; the sample count still
        // shows how much posterior mass backs each vote
        println!("({} posterior samples behind the vote)", sample_preds.len());
    } else {
        let std = eval::predictive_std(&sample_preds)?;
        let mean_std: f32 = std.as_f32().iter().sum::<f32>() / std.element_count() as f32;
        println!(
            "epistemic std over {} posterior samples: {:.4} (per-point mean)",
            sample_preds.len(),
            mean_std
        );
    }
    println!("predictions (first 4): {:?}", &pred.as_f32()[..4]);
    println!("targets     (first 4): {:?}", &b.y.as_f32()[..4]);
    Ok(())
}
