//! SVGD vs deep ensemble on noisy linear regression — demonstrates the
//! paper's Appendix-B inference encoding and the effect of the repulsive
//! kernel term: SVGD particles stay diverse where independent SGD members
//! collapse toward the same mode.
//!
//! ```sh
//! make artifacts && cargo run --release --example svgd_regression
//! ```

use anyhow::Result;
use push::data::{synth, DataLoader};
use push::device::CostModel;
use push::infer::svgd::median_lengthscale;
use push::infer::{DeepEnsemble, Infer, Svgd, SvgdConfig};
use push::runtime::{artifacts_dir, Manifest, Tensor};
use push::util::flags::Flags;
use push::{NelConfig, PushDist};

/// Mean pairwise L2 distance between particle parameter vectors — the
/// diversity measure the repulsion term acts on.
fn diversity(params: &[Tensor]) -> f64 {
    let n = params.len();
    let mut total = 0.0;
    let mut count = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            let d: f64 = params[i]
                .as_f32()
                .iter()
                .zip(params[j].as_f32())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            total += d.sqrt();
            count += 1;
        }
    }
    total / count.max(1) as f64
}

fn main() -> Result<()> {
    let flags = Flags::from_env().map_err(anyhow::Error::msg)?;
    let particles = flags.usize_or("particles", 8).map_err(anyhow::Error::msg)?;
    let epochs = flags.usize_or("epochs", 25).map_err(anyhow::Error::msg)?;
    let manifest = Manifest::load(artifacts_dir())?;
    let cfg = || NelConfig {
        num_devices: 2,
        cache_size: 8,
        cost: CostModel::default(),
        seed: 55,
        ..NelConfig::default()
    };

    let model = manifest.model("mlp_small")?.clone();
    let data = synth::linear(model.batch() * 6, model.x_shape[1], 0.1, 13);
    let mk_loader = || {
        DataLoader::new(data.clone(), model.batch(), true, 17).with_max_batches(6)
    };

    // ---------------- SVGD (kernel artifact on the leader device) --------
    let pd = PushDist::new(&manifest, "mlp_small", cfg())?;
    let mut svgd = Svgd::new(
        pd,
        SvgdConfig {
            particles,
            lr: 5e-3,
            lengthscale: 5.0,
            median_heuristic: true, // h tracks the particle spread
            prior_std: Some(10.0),  // Gaussian prior => Appendix-B score term
            force_native: false,
        },
    )?;
    let mut loader = mk_loader();
    println!("SVGD on {} particles (kernel artifact: {})", particles,
             svgd.pd().svgd_artifact(particles).is_some());
    let mut svgd_curve = Vec::new();
    for _ in 0..epochs {
        let rep = svgd.train(&mut loader, 1)?;
        svgd_curve.push(rep.final_loss());
    }
    let svgd_params: Vec<Tensor> = svgd.pd().drain_params()?.into_values().collect();

    // ---------------- independent ensemble, same budget -------------------
    let pd = PushDist::new(&manifest, "mlp_small", cfg())?;
    let mut ens = DeepEnsemble::new(pd, particles, 5e-3)?;
    let mut loader = mk_loader();
    let mut ens_curve = Vec::new();
    for _ in 0..epochs {
        let rep = ens.train(&mut loader, 1)?;
        ens_curve.push(rep.final_loss());
    }
    let ens_params: Vec<Tensor> = ens.pd().drain_params()?.into_values().collect();

    println!("\nepoch   svgd_loss   ensemble_loss");
    for e in (0..epochs).step_by(4.max(epochs / 6)) {
        println!("{e:>5}   {:>9.4}   {:>13.4}", svgd_curve[e], ens_curve[e]);
    }
    println!(
        "{:>5}   {:>9.4}   {:>13.4}",
        epochs - 1,
        svgd_curve[epochs - 1],
        ens_curve[epochs - 1]
    );

    let div_svgd = diversity(&svgd_params);
    let div_ens = diversity(&ens_params);
    println!("\n== particle diversity ==");
    println!(
        "parameter space (mean pairwise distance): svgd {div_svgd:.3} vs ensemble {div_ens:.3}"
    );

    // kernel interaction strength under the median heuristic: off-diagonal
    // k values ~ exp(-0.5 log n) — the repulsion term is ACTIVE, unlike a
    // fixed small lengthscale where k_ij ~ 0 in high dimensions.
    let h = median_lengthscale(&svgd_params);
    let mut k_sum = 0.0f64;
    let mut k_cnt = 0usize;
    for i in 0..svgd_params.len() {
        for j in (i + 1)..svgd_params.len() {
            let d2: f32 = svgd_params[i]
                .as_f32()
                .iter()
                .zip(svgd_params[j].as_f32())
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            k_sum += (-0.5 * d2 / (h * h)).exp() as f64;
            k_cnt += 1;
        }
    }
    println!(
        "median-heuristic h = {h:.2}; mean off-diagonal k_ij = {:.3} (repulsion active)",
        k_sum / k_cnt as f64
    );

    // function-space diversity: per-point std of particle predictions
    let fdiv = |pd: &push::PushDist, pids: &[push::Pid], x: &Tensor| -> f64 {
        let preds: Vec<Tensor> = pids
            .iter()
            .map(|p| pd.forward(*p, x.clone()).wait().unwrap().tensor().unwrap())
            .collect();
        let n = preds.len() as f64;
        let len = preds[0].element_count();
        let mut total = 0.0;
        for i in 0..len {
            let m: f64 = preds.iter().map(|p| p.as_f32()[i] as f64).sum::<f64>() / n;
            let v: f64 =
                preds.iter().map(|p| (p.as_f32()[i] as f64 - m).powi(2)).sum::<f64>() / n;
            total += v.sqrt();
        }
        total / len as f64
    };
    let b = mk_loader().epoch()[0].clone();
    let svgd_pids = svgd.pids();
    let ens_pids = ens.pids();
    println!(
        "function space (mean per-point pred std): svgd {:.4} vs ensemble {:.4}",
        fdiv(svgd.pd(), &svgd_pids, &b.x),
        fdiv(ens.pd(), &ens_pids, &b.x)
    );

    // posterior-mean predictions agree with targets
    let b = mk_loader().epoch()[0].clone();
    let pred = svgd.predict_mean(&b.x)?;
    println!("\nSVGD posterior mean (first 4): {:?}", &pred.as_f32()[..4]);
    println!("targets             (first 4): {:?}", &b.y.as_f32()[..4]);
    Ok(())
}
