#!/usr/bin/env python3
"""Bench-smoke regression gate (CI).

Compares a fresh l3_microbench run (the JSON written via PUSH_BENCH_JSON)
against the analytic accounting committed in BENCH_l3.json: every entry in
its `gates` array asserts

    mean(slow case) / mean(fast case)  >=  min_ratio

where min_ratio is the conservative analytic advantage divided by 2 — i.e.
the build fails only when an optimized path has regressed by more than 2x
relative to what the byte/op accounting says it must beat. Gated cases are
all hermetic, so the check needs no artifacts and no PJRT.

A gate may carry `requires_feature`: it is checked only when that feature
name appears in the measured JSON's `features` array (the bench emits its
compiled feature set). This keeps scalar/simd pairs honest — on a build
without `--features simd` both legs run the same scalar tier, so the pair's
ratio says nothing about the vector path and the gate is reported SKIPPED
instead of failing on missing speedup.

Usage: check_bench_gates.py BENCH_l3.json measured.json
       check_bench_gates.py --selftest   (run the committed fixtures)
"""

import json
import os
import sys


def check(baseline: dict, measured: dict, baseline_name: str) -> int:
    gates = baseline.get("gates", [])
    if not gates:
        print(f"error: no gates defined in {baseline_name}")
        return 1
    cases = measured.get("cases", {})
    features = set(measured.get("features", []))

    failures = []
    checked = 0
    print(f"{'gate (slow / fast)':<64} {'ratio':>8} {'min':>6}  verdict")
    for gate in gates:
        fast, slow = gate["fast"], gate["slow"]
        min_ratio = float(gate["min_ratio"])
        need = gate.get("requires_feature")
        if need and need not in features:
            print(
                f"{slow + ' / ' + fast:<64} {'-':>8} {min_ratio:>6}  "
                f"SKIPPED (needs --features {need})"
            )
            continue
        missing = [name for name in (fast, slow) if name not in cases]
        if missing:
            failures.append(f"missing case(s) {missing} for gate {slow}/{fast}")
            print(f"{slow + ' / ' + fast:<64} {'-':>8} {min_ratio:>6}  MISSING")
            continue
        fast_us = float(cases[fast]["mean_us"])
        slow_us = float(cases[slow]["mean_us"])
        if fast_us <= 0:
            failures.append(f"non-positive mean for {fast}: {fast_us}")
            continue
        ratio = slow_us / fast_us
        ok = ratio >= min_ratio
        checked += 1
        print(f"{slow + ' / ' + fast:<64} {ratio:>8.2f} {min_ratio:>6}  {'ok' if ok else 'REGRESSED'}")
        if not ok:
            failures.append(
                f"{fast} regressed: {slow}/{fast} = {ratio:.2f}x < required {min_ratio}x "
                f"(fast {fast_us:.1f}us, slow {slow_us:.1f}us)"
            )

    if failures:
        print("\nbench gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nall gates passed ({checked} checked, {len(gates) - checked} skipped)")
    return 0


def selftest() -> int:
    """Run the checker against the committed fixtures: a passing run, a
    regressed run (must fail), and a scalar build where the feature-gated
    pairs must be SKIPPED rather than failed."""
    fixtures = os.path.join(os.path.dirname(os.path.abspath(__file__)), "fixtures")
    with open(os.path.join(fixtures, "gates_baseline.json")) as f:
        baseline = json.load(f)

    expectations = [
        ("measured_pass.json", 0),
        ("measured_regressed.json", 1),
        ("measured_no_simd.json", 0),
    ]
    bad = []
    for name, want in expectations:
        with open(os.path.join(fixtures, name)) as f:
            measured = json.load(f)
        print(f"--- fixture {name} (expect exit {want})")
        got = check(baseline, measured, "gates_baseline.json")
        print()
        if got != want:
            bad.append(f"{name}: exit {got}, expected {want}")
    if bad:
        print("selftest FAILED:")
        for b in bad:
            print(f"  - {b}")
        return 1
    print(f"selftest passed ({len(expectations)} fixtures)")
    return 0


def main() -> int:
    if len(sys.argv) == 2 and sys.argv[1] == "--selftest":
        return selftest()
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        baseline = json.load(f)
    with open(sys.argv[2]) as f:
        measured = json.load(f)
    return check(baseline, measured, sys.argv[1])


if __name__ == "__main__":
    sys.exit(main())
