#!/usr/bin/env python3
"""Bench-smoke regression gate (CI).

Compares a fresh l3_microbench run (the JSON written via PUSH_BENCH_JSON)
against the analytic accounting committed in BENCH_l3.json: every entry in
its `gates` array asserts

    mean(slow case) / mean(fast case)  >=  min_ratio

where min_ratio is the conservative analytic advantage divided by 2 — i.e.
the build fails only when an optimized path has regressed by more than 2x
relative to what the byte/op accounting says it must beat. Gated cases are
all hermetic, so the check needs no artifacts and no PJRT.

Usage: check_bench_gates.py BENCH_l3.json measured.json
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        baseline = json.load(f)
    with open(sys.argv[2]) as f:
        measured = json.load(f)

    gates = baseline.get("gates", [])
    if not gates:
        print(f"error: no gates defined in {sys.argv[1]}")
        return 1
    cases = measured.get("cases", {})

    failures = []
    print(f"{'gate (slow / fast)':<64} {'ratio':>8} {'min':>6}  verdict")
    for gate in gates:
        fast, slow = gate["fast"], gate["slow"]
        min_ratio = float(gate["min_ratio"])
        missing = [name for name in (fast, slow) if name not in cases]
        if missing:
            failures.append(f"missing case(s) {missing} for gate {slow}/{fast}")
            print(f"{slow + ' / ' + fast:<64} {'-':>8} {min_ratio:>6}  MISSING")
            continue
        fast_us = float(cases[fast]["mean_us"])
        slow_us = float(cases[slow]["mean_us"])
        if fast_us <= 0:
            failures.append(f"non-positive mean for {fast}: {fast_us}")
            continue
        ratio = slow_us / fast_us
        ok = ratio >= min_ratio
        print(f"{slow + ' / ' + fast:<64} {ratio:>8.2f} {min_ratio:>6}  {'ok' if ok else 'REGRESSED'}")
        if not ok:
            failures.append(
                f"{fast} regressed: {slow}/{fast} = {ratio:.2f}x < required {min_ratio}x "
                f"(fast {fast_us:.1f}us, slow {slow_us:.1f}us)"
            )

    if failures:
        print("\nbench gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nall {len(gates)} bench gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
