#!/usr/bin/env python3
"""Native-model accuracy gate (CI).

Checks a fresh `push bench native-acc` run (the JSON saved under
bench_results/) against the thresholds committed in ACC_GATES.json. Every
entry in its `gates` array addresses one measured row by (model, method)
and asserts one of three machine-readable forms on `metric`:

    {"model": M, "method": A, "metric": "accuracy", "min": X}
    {"model": M, "method": A, "metric": "mse", "max": X}
    {"model": M, "method": A, "metric": "accuracy",
     "beats": {"model": M2, "method": A2, "margin": D}}

The `beats` form asserts value(M, A) - value(M2, A2) >= D — e.g. the
spiral MLP posterior must beat the linear control by a fixed margin that a
linear decision rule provably cannot close (data/synth.rs bounds the best
linear cut on the 1.5-turn spiral below 80%). Every gated row is a
hermetic closed-form native model: no artifacts, no PJRT, so this runs on
a bare CI runner.

Usage: check_accuracy_gates.py ACC_GATES.json bench_results/native_acc.json
"""

import json
import sys


def row_value(rows, model, method, metric):
    for r in rows:
        if r.get("model") == model and r.get("method") == method:
            return r.get(metric)
    return None


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        baseline = json.load(f)
    with open(sys.argv[2]) as f:
        measured = json.load(f)

    gates = baseline.get("gates", [])
    if not gates:
        print(f"error: no gates defined in {sys.argv[1]}")
        return 1
    rows = measured.get("rows", [])

    failures = []
    print(f"{'gate':<58} {'value':>8} {'bound':>18}  verdict")
    for gate in gates:
        model, method, metric = gate["model"], gate["method"], gate["metric"]
        label = f"{model}/{method} {metric}"
        value = row_value(rows, model, method, metric)
        if value is None:
            failures.append(f"no measured {metric} row for {model}/{method}")
            print(f"{label:<58} {'-':>8} {'-':>18}  MISSING")
            continue
        value = float(value)
        if "beats" in gate:
            b = gate["beats"]
            margin = float(b["margin"])
            other = row_value(rows, b["model"], b["method"], metric)
            if other is None:
                failures.append(
                    f"no measured {metric} row for control {b['model']}/{b['method']}"
                )
                print(f"{label:<58} {value:>8.2f} {'-':>18}  MISSING CONTROL")
                continue
            other = float(other)
            ok = value - other >= margin
            bound = f">= {b['method']}+{margin:g}"
            print(f"{label:<58} {value:>8.2f} {bound:>18}  {'ok' if ok else 'FAILED'}")
            if not ok:
                failures.append(
                    f"{model}/{method} {metric} {value:.2f} does not beat "
                    f"{b['model']}/{b['method']} ({other:.2f}) by {margin:g}"
                )
        elif "min" in gate:
            lo = float(gate["min"])
            ok = value >= lo
            print(f"{label:<58} {value:>8.2f} {'>= %g' % lo:>18}  {'ok' if ok else 'FAILED'}")
            if not ok:
                failures.append(f"{model}/{method} {metric} {value:.2f} < required {lo:g}")
        elif "max" in gate:
            hi = float(gate["max"])
            ok = value <= hi
            print(f"{label:<58} {value:>8.2f} {'<= %g' % hi:>18}  {'ok' if ok else 'FAILED'}")
            if not ok:
                failures.append(f"{model}/{method} {metric} {value:.2f} > allowed {hi:g}")
        else:
            failures.append(f"gate for {model}/{method} has no min/max/beats clause")

    if failures:
        print("\naccuracy gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nall {len(gates)} accuracy gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
